"""Table II analog: DNN inference accuracy — float32 vs exact Posit<16,1>
vs PLAM Posit<16,1>.

Datasets are synthetic stand-ins (no offline access to ISOLET/HAR/
MNIST/SVHN/CIFAR-10) with matched input dims / class counts / model
topologies from the paper's Table I.  The claim under test is accuracy
*parity*: PLAM inference ~= exact-posit inference ~= float32, which is
dataset-independent in the regime the paper studies (bounded 11.1%
multiplier error vs. DNN noise floor).

Models are trained in float32 (the paper also trains posit16 — covered
by the posit_quant training benchmark below), then evaluated under the
three numerics modes like the paper's Table II columns.
"""
from __future__ import annotations



from repro.core.modes import NumericsConfig
from repro.data.synthetic import classification_dataset, image_dataset
from repro.paper.models import (
    accuracy,
    cifarnet_apply,
    cifarnet_init,
    lenet5_apply,
    lenet5_init,
    mlp_apply,
    mlp_init,
    train_classifier,
)

F32 = NumericsConfig(mode="f32")
P16 = NumericsConfig(mode="posit_quant", n=16, es=1)
PLAM = NumericsConfig(mode="plam_sim", n=16, es=1)
MITCH = NumericsConfig(mode="mitchell_f32")

SETUPS = [
    # (name, kind, init, apply, data args, train args)   — paper Table I
    ("isolet-syn", "mlp", (617, 128, 64, 26), dict(n=4000, epochs=12, lr=1e-3)),
    ("ucihar-syn", "mlp", (561, 512, 512, 6), dict(n=4000, epochs=10, lr=1e-3)),
    ("mnist-syn", "lenet5", dict(hw=28, ch=1, classes=10), dict(n=3000, epochs=8, lr=1e-3)),
    ("svhn-syn", "lenet5", dict(hw=28, ch=3, classes=10), dict(n=3000, epochs=8, lr=1e-3)),
    ("cifar10-syn", "cifarnet", dict(hw=32, ch=3, classes=10), dict(n=3000, epochs=8, lr=1e-3)),
]


def run_setup(name, kind, arch, targs, seed=0, eval_modes=None):
    eval_modes = eval_modes or {"float32": F32, "posit16": P16, "plam16": PLAM}
    n = targs["n"]
    if kind == "mlp":
        x, y = classification_dataset(seed, n + 1000, arch[0], arch[-1])
        init = lambda k: mlp_init(k, arch)
        apply_fn = mlp_apply
    elif kind == "lenet5":
        x, y = image_dataset(seed, n + 1000, arch["hw"], arch["ch"], arch["classes"])
        init = lambda k: lenet5_init(k, arch["ch"], arch["classes"], arch["hw"])
        apply_fn = lenet5_apply
    else:
        x, y = image_dataset(seed, n + 1000, arch["hw"], arch["ch"], arch["classes"])
        init = lambda k: cifarnet_init(k, arch["ch"], arch["classes"], arch["hw"])
        apply_fn = cifarnet_apply

    xtr, ytr, xte, yte = x[:n], y[:n], x[n:], y[n:]
    params = train_classifier(init, apply_fn, xtr, ytr,
                              epochs=targs["epochs"], lr=targs["lr"], seed=seed)
    row = {"dataset": name}
    for mode_name, ncfg in eval_modes.items():
        accs = accuracy(apply_fn, params, xte, yte, ncfg, topk=(1, 5))
        row[f"{mode_name}_top1"] = accs[1]
        row[f"{mode_name}_top5"] = accs[5]
    return row


def main(quick: bool = False):
    rows = []
    setups = SETUPS[:2] if quick else SETUPS
    for name, kind, arch, targs in setups:
        t = dict(targs)
        if quick:
            t.update(n=2200, epochs=6)
        rows.append(run_setup(name, kind, arch, t))
        r = rows[-1]
        print(f"{name}: f32={r['float32_top1']:.4f} posit16={r['posit16_top1']:.4f} "
              f"plam16={r['plam16_top1']:.4f}", flush=True)
    print("\ndataset,f32_top1,posit16_top1,plam16_top1,f32_top5,posit16_top5,plam16_top5")
    for r in rows:
        print(f"{r['dataset']},{r['float32_top1']:.4f},{r['posit16_top1']:.4f},"
              f"{r['plam16_top1']:.4f},{r['float32_top5']:.4f},{r['posit16_top5']:.4f},"
              f"{r['plam16_top5']:.4f}")
    # Paper claim: negligible degradation.  Gate at <= 2 points top-1.
    for r in rows:
        drop = r["float32_top1"] - r["plam16_top1"]
        print(f"# {r['dataset']}: plam16 vs f32 top-1 delta = {drop:+.4f}")
    return rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
