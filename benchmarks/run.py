"""Benchmark driver: one section per paper table/figure + system benches.

  table2   — DNN inference accuracy parity (paper Table II)
  hwcost   — multiplier area/power/delay model (paper Table III, Figs 5-6)
  error    — PLAM error bound & distribution (paper Sec. III-C / eq. 24)
  kernels  — Pallas/sim engine micro-benchmarks
  train    — posit16-quantized LM training curve (system-level)

``python -m benchmarks.run`` runs everything in quick mode and prints
CSV blocks; ``--full`` uses the full Table II protocol.
"""
from __future__ import annotations

import argparse


def _section(name):
    print(f"\n##### {name} " + "#" * max(1, 60 - len(name)), flush=True)


def bench_train_quick():
    """Posit16 vs f32 LM training on synthetic data (loss parity)."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.models import build
    from repro.optim.optimizers import OptConfig, init_state
    from repro.train.loop import TrainConfig, make_train_step

    dcfg = DataConfig(seed=0, vocab=128, seq_len=64, global_batch=16)
    print("mode,steps,first_loss,final_loss")
    for mode in ["f32", "posit_quant"]:
        cfg = ModelConfig(
            name="bench", family="dense", n_layers=2, d_model=128, n_heads=4,
            n_kv=2, head_dim=32, d_ff=256, vocab=128,
            numerics=NumericsConfig(mode=mode, n=16, es=1),
        )
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
        step = jax.jit(make_train_step(api.train_loss, tcfg))
        state = init_state(tcfg.opt, params)
        losses = []
        for i in range(40):
            params, state, m = step(params, state, lm_batch(dcfg, i))
            losses.append(float(m["loss"]))
        print(f"{mode},40,{losses[0]:.6f},{losses[-1]:.6f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke subset: kernels + error sections only")
    args = ap.parse_args()

    def want(name):
        if args.only is not None:
            return args.only == name
        if args.quick:
            return name in ("kernels", "error")
        return True

    if want("error"):
        _section("error: PLAM approximation error (paper Sec. III-C)")
        from benchmarks import error_analysis
        error_analysis.main()

    if want("hwcost"):
        _section("hwcost: multiplier hardware model (paper Table III / Fig. 5)")
        from benchmarks import hw_cost
        hw_cost.main()

    if want("kernels"):
        _section("kernels: simulation engines")
        from benchmarks import kernel_bench
        kernel_bench.main()

    if want("train"):
        _section("train: posit16 LM training parity")
        bench_train_quick()

    if want("table2"):
        _section("table2: DNN inference accuracy (paper Table II)")
        from benchmarks import table2_accuracy
        table2_accuracy.main(quick=not args.full)


if __name__ == "__main__":
    main()
