"""Benchmark driver: one section per paper table/figure + system benches.

  table2   — DNN inference accuracy parity (paper Table II)
  hwcost   — multiplier area/power/delay model (paper Table III, Figs 5-6)
  error    — PLAM error bound & distribution (paper Sec. III-C / eq. 24)
  kernels  — Pallas/sim engine micro-benchmarks
  train    — posit16-quantized LM training curve (system-level)
  numerics — per-site policy accuracy/cost frontier (BENCH_numerics.json)
  conformance — oracle-matrix throughput + agreement (same JSON)

``python -m benchmarks.run`` runs everything in quick mode and prints
CSV blocks; ``--full`` uses the full Table II protocol.
"""
from __future__ import annotations

import argparse
import json


def _section(name):
    print(f"\n##### {name} " + "#" * max(1, 60 - len(name)), flush=True)


def bench_train_quick():
    """Posit16 vs f32 LM training on synthetic data (loss parity)."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.models import build
    from repro.optim.optimizers import OptConfig, init_state
    from repro.train.loop import TrainConfig, make_train_step

    dcfg = DataConfig(seed=0, vocab=128, seq_len=64, global_batch=16)
    print("mode,steps,first_loss,final_loss")
    for mode in ["f32", "posit_quant"]:
        cfg = ModelConfig(
            name="bench", family="dense", n_layers=2, d_model=128, n_heads=4,
            n_kv=2, head_dim=32, d_ff=256, vocab=128,
            numerics=NumericsConfig(mode=mode, n=16, es=1),
        )
        api = build(cfg)
        params = api.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
        step = jax.jit(make_train_step(api.train_loss, tcfg))
        state = init_state(tcfg.opt, params)
        losses = []
        for i in range(40):
            params, state, m = step(params, state, lm_batch(dcfg, i))
            losses.append(float(m["loss"]))
        print(f"{mode},40,{losses[0]:.6f},{losses[-1]:.6f}")


def bench_numerics(json_path="BENCH_numerics.json", budget=0.05):
    """Per-site policy frontier: uniform f32, uniform PLAM, calibrated.

    Trains a small dense LM briefly in f32 (so the loss surface is not
    random init), then evaluates >= 3 policy points — eval loss, top-1
    logits agreement vs f32, and the unit-gate multiplier-cost estimate
    relative to uniform f32 — and runs the greedy calibration sweep.
    Writes the frontier to ``json_path`` (CI uploads it next to
    BENCH_serving.json).
    """
    import jax
    from repro.configs.base import ModelConfig
    from repro.core.policy import parse_policy, policy_to_str
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.models import build
    from repro.numerics.calibrate import calibrate, estimate_cost, top1_agreement
    from repro.optim.optimizers import OptConfig, init_state
    from repro.train.loop import TrainConfig, make_train_step

    cfg = ModelConfig(
        name="bench-dense", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv=2, head_dim=32, d_ff=256, vocab=128,
    ).with_numerics("default=f32")
    dcfg = DataConfig(seed=0, vocab=128, seq_len=64, global_batch=16)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
    step = jax.jit(make_train_step(api.train_loss, tcfg))
    state = init_state(tcfg.opt, params)
    for i in range(30):
        params, state, _ = step(params, state, lm_batch(dcfg, i))
    eval_batch = lm_batch(dcfg, 1000)

    def point(name, numerics):
        pcfg = cfg.with_numerics(numerics)
        papi = build(pcfg)
        loss = float(jax.jit(papi.train_loss)(params, eval_batch))
        logits, _ = jax.jit(papi.prefill)(params, {"tokens": eval_batch["tokens"]})
        return {
            "name": name,
            "policy": policy_to_str(numerics),
            "loss": loss,
            "logits": logits,
            "cost_rel_f32": estimate_cost(cfg, numerics) / cost_f32,
        }

    cost_f32 = estimate_cost(cfg, parse_policy("default=f32"))
    # aggressive 8-bit PLAM target with an exact-posit16 fallback: the
    # 16-bit PLAM matches f32 within any sane budget (the paper's
    # no-degradation claim), so the interesting frontier point is how
    # far BELOW 16 bits calibration can push each site
    res = calibrate(
        cfg, params, eval_batch, budget=budget,
        target="plam_sim:8:0", fallback="plam_sim:16:1",
    )
    points = [
        point("uniform_f32", parse_policy("default=f32")),
        point("uniform_plam16", parse_policy("default=plam_sim:16:1")),
        point("calibrated_mixed", res.policy),
    ]
    ref_logits = points[0].pop("logits")
    points[0]["top1_agree"] = 1.0
    for p in points[1:]:
        p["top1_agree"] = top1_agreement(ref_logits, p.pop("logits"))

    print("name,policy,loss,top1_agree,cost_rel_f32")
    for p in points:
        print(f"{p['name']},\"{p['policy']}\",{p['loss']:.6f},"
              f"{p['top1_agree']:.4f},{p['cost_rel_f32']:.4f}")
    out = {
        "model": cfg.name,
        "budget": budget,
        "base_loss": res.base_loss,
        "calibrated_policy": res.policy_str,
        "decisions": res.decisions,
        "points": points,
    }
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {json_path}")


def bench_conformance(json_path="BENCH_numerics.json", count=1 << 16):
    """Oracle-matrix throughput + agreement on one batch of plam_mul.

    Times every conformance implementation on the same ``count``-pattern
    Posit<16,1> batch (patterns/s) and differentially compares each one
    against the JAX reference — the mismatch count is asserted to be 0,
    so a red bench run means the implementations diverged, not just got
    slow.  Results merge into ``json_path`` under the ``conformance``
    key, next to the numerics frontier.
    """
    import os
    import time

    import numpy as np

    from repro.conformance import default_impls, outputs_equal
    from repro.numerics import PositSpec

    spec = PositSpec(16, 1)
    rng = np.random.default_rng(0)
    pa = rng.integers(0, 1 << 16, count).astype(np.int32)
    pb = rng.integers(0, 1 << 16, count).astype(np.int32)
    impls = default_impls(spec)
    # the pure-Python golden model is ~1e4x slower; time a slice and
    # differentially check the same slice rather than the full batch
    golden_lanes = 2048
    ref = np.asarray(impls["jax"].run("plam_mul", (pa, pb), spec))

    rows = []
    print("impl,patterns_per_s,lanes,mismatches")
    for name, im in impls.items():
        lanes = golden_lanes if name == "golden" else count
        ins = (pa[:lanes], pb[:lanes])
        im.run("plam_mul", ins, spec)  # warm the jit caches
        t0 = time.perf_counter()
        out = im.run("plam_mul", ins, spec)
        dt = time.perf_counter() - t0
        bad = int((~outputs_equal(ref[:lanes], np.asarray(out))).sum())
        assert bad == 0, f"{name} disagrees with jax on {bad} lanes"
        rows.append({"impl": name, "patterns_per_s": lanes / dt,
                     "lanes": lanes, "mismatches": bad})
        print(f"{name},{lanes / dt:.3e},{lanes},{bad}")

    doc = {}
    if os.path.exists(json_path):
        with open(json_path) as f:
            doc = json.load(f)
    doc["conformance"] = {"spec": [spec.n, spec.es], "op": "plam_mul",
                          "rows": rows}
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# merged conformance section into {json_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke subset: kernels + error + numerics sections")
    ap.add_argument("--numerics-json", default="BENCH_numerics.json",
                    help="where the numerics section writes its frontier")
    args = ap.parse_args()

    def want(name):
        if args.only is not None:
            return args.only == name
        if args.quick:
            return name in ("kernels", "error", "numerics", "conformance")
        return True

    if want("error"):
        _section("error: PLAM approximation error (paper Sec. III-C)")
        from benchmarks import error_analysis
        error_analysis.main()

    if want("hwcost"):
        _section("hwcost: multiplier hardware model (paper Table III / Fig. 5)")
        from benchmarks import hw_cost
        hw_cost.main()

    if want("kernels"):
        _section("kernels: simulation engines")
        from benchmarks import kernel_bench
        kernel_bench.main()

    if want("train"):
        _section("train: posit16 LM training parity")
        bench_train_quick()

    if want("numerics"):
        _section("numerics: per-site policy accuracy/cost frontier")
        bench_numerics(json_path=args.numerics_json)

    if want("conformance"):
        _section("conformance: oracle-matrix throughput + agreement")
        bench_conformance(json_path=args.numerics_json)

    if want("table2"):
        _section("table2: DNN inference accuracy (paper Table II)")
        from benchmarks import table2_accuracy
        table2_accuracy.main(quick=not args.full)


if __name__ == "__main__":
    main()
