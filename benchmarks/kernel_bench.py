"""Kernel micro-benchmarks: PLAM simulation engines.

On this CPU container the numbers measure the *simulator* (Pallas
interpret mode executes kernel bodies as jnp on host); on TPU the same
entry points lower through Mosaic.  What is portable and meaningful
here: the relative cost of simulation fidelities and the codec
throughput — the quantities a user picks a mode by.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.modes import NumericsConfig, nmatmul
from repro.numerics import P16, encode
from repro.kernels import plam_matmul_bits, posit_quantize


def timeit(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main():
    rng = np.random.default_rng(0)
    rows = []
    m = k = n = 256
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    xb, wb = encode(x, P16), encode(w, P16)

    for mode in ["f32", "bf16", "posit_quant", "plam_sim", "mitchell_f32"]:
        ncfg = NumericsConfig(mode=mode)
        us = timeit(jax.jit(lambda a, b: nmatmul(a, b, ncfg)), x, w)
        rows.append((f"nmatmul_{mode}_{m}x{k}x{n}", us, 2 * m * k * n / us / 1e3))

    us = timeit(lambda a, b: plam_matmul_bits(a, b, P16, bm=128, bn=128, bk=128), xb, wb)
    rows.append((f"pallas_plam_matmul_{m}x{k}x{n}", us, 2 * m * k * n / us / 1e3))

    big = jnp.asarray(rng.standard_normal((1024, 1024)).astype(np.float32))
    us = timeit(lambda v: posit_quantize(v, P16), big)
    rows.append(("pallas_posit_quantize_1M", us, big.size * 4 / us / 1e3))

    print("name,us_per_call,derived_mflops_or_MBps")
    for name, us, d in rows:
        print(f"{name},{us:.1f},{d:.1f}")


if __name__ == "__main__":
    main()
