"""Paper Sec. III-C: PLAM approximation-error characterization.

Empirically maps the relative error over the (fa, fb) unit square,
verifies the analytic eq. (24), the 11.1% bound at fa=fb=0.5, and that
regime/exponent fields do NOT affect the error (the paper's key
observation), plus the mean error under DNN-like operand distributions.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.numerics import P16, decode, encode, plam_product_f32, plam_relative_error


def error_grid(n=64):
    fa = np.linspace(0, 1, n, endpoint=False)
    fb = np.linspace(0, 1, n, endpoint=False)
    a = encode(jnp.asarray((1 + fa).astype(np.float32)), P16)
    b = encode(jnp.asarray((1 + fb).astype(np.float32)), P16)
    err = np.asarray(plam_relative_error(a[:, None], b[None, :], P16))
    return fa, fb, err


def scale_independence(trials=64):
    """Same fractions, different regimes/exponents -> same error."""
    rng = np.random.default_rng(0)
    fa, fb = 0.3125, 0.625  # exactly representable fractions
    errs = []
    for _ in range(trials):
        sa = 2.0 ** rng.integers(-10, 10)
        sb = 2.0 ** rng.integers(-10, 10)
        a = encode(jnp.float32(sa * (1 + fa)), P16)
        b = encode(jnp.float32(sb * (1 + fb)), P16)
        va = float(decode(a, P16)) * float(decode(b, P16))
        vp = float(plam_product_f32(a, b, P16))
        errs.append((va - vp) / va)
    return np.asarray(errs)


def dnn_distribution_error(n=200_000):
    """Mean |error| for N(0,1) operands (DNN weight/activation regime)."""
    rng = np.random.default_rng(1)
    a = encode(jnp.asarray(rng.standard_normal(n).astype(np.float32)), P16)
    b = encode(jnp.asarray(rng.standard_normal(n).astype(np.float32)), P16)
    err = np.asarray(plam_relative_error(a, b, P16))
    return err


def main():
    _, _, grid = error_grid()
    print(f"max grid error: {grid.max():.6f} (bound 1/9 = {1/9:.6f})")
    am = np.unravel_index(grid.argmax(), grid.shape)
    print(f"argmax at fa={am[0]/64:.3f} fb={am[1]/64:.3f} (paper: 0.5, 0.5)")
    si = scale_independence()
    print(f"scale independence: err std over regimes/exponents = {si.std():.2e}")
    de = dnn_distribution_error()
    print(f"N(0,1) operands: mean rel err {de.mean()*100:.2f}%  p99 {np.percentile(de,99)*100:.2f}%")
    print("name,value")
    print(f"max_error,{grid.max():.6f}")
    print(f"bound,{1/9:.6f}")
    print(f"mean_dnn_error,{de.mean():.6f}")


if __name__ == "__main__":
    main()
