"""Analytical unit-gate cost model for multiplier hardware (Table III /
Figs. 5-6 analog).

We cannot run Vivado/Design Compiler offline, so this reproduces the
paper's hardware *trend* with a standard unit-gate model (XOR=2, AND/OR=1,
FA=7 gate-equivalents, barrel shifter = 2*w*log2(w), LZC = 3*w):
area/power/delay proxies for

  * exact posit multiplier   (decode + (fb+1)^2 array multiplier + RNE + encode)
  * PLAM                     (decode + ONE (fb + es + log-regime)-bit adder + RNE + encode)
  * IEEE-like float multiplier (no regime machinery, mantissa array mult)

The claim under test (paper Sec. V): PLAM removes the fraction
multiplier — the dominant block (Fig. 1) — so area/power drop steeply
with bitwidth (reported: -72.86% area, -81.79% power at 32-bit vs [16])
while delay improves modestly (-17.01%), and posit decode/encode remains
the delay bottleneck.  The model is labeled MODEL-BASED in EXPERIMENTS.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

XOR, AND, OR, NOT = 2.0, 1.0, 1.0, 0.5
FA = 2 * XOR + 2 * AND + OR  # full adder ~ 7 gate-equivalents
MUX = 3.0


def _shifter(w):  # barrel shifter area
    return MUX * w * max(1, math.ceil(math.log2(max(w, 2))))


def _lzc(w):  # leading-zero/one counter
    return 3.0 * w


def _adder(w):  # ripple-free (CLA-ish) adder area
    return FA * w


def _array_mult(m):  # m x m array multiplier
    return AND * m * m + FA * m * (m - 2)


@dataclass
class Cost:
    area: float
    delay: float

    @property
    def power(self):  # activity-weighted proxy: switching ~ area^1.15
        return self.area ** 1.15


def posit_decode_cost(n):
    # 2's complement + LZC + left shifter, for each operand
    return _adder(n) + _lzc(n) + _shifter(n)


def posit_encode_cost(n):
    # regime construction shifter + rounding incrementer + complement
    return _shifter(n) + _adder(n) + _adder(n)


def exact_posit_mult(n, es):
    fb = n - 3 - es
    m = fb + 1
    area = (
        2 * posit_decode_cost(n)
        + _array_mult(m)                # the fraction multiplier (Fig. 1)
        + _adder(n)                     # scale addition
        + posit_encode_cost(n)
    )
    # Delay: the paper observes posit delay is dominated by variable-
    # length field detection (decode/encode), not the multiplier — the
    # synthesized multiplier is a log-depth Wallace tree.
    delay = (
        5 * math.log2(n)                # decode: LZC + barrel shift
        + 4 * math.log2(m) + math.log2(2 * m)  # Wallace tree + CPA
        + 5 * math.log2(n)              # encode: shift + round + cpl
    )
    return Cost(area, delay)


def plam_posit_mult(n, es):
    fb = n - 3 - es
    w = fb + es + math.ceil(math.log2(n))  # the Fig. 4 log-fixed word
    area = (
        2 * posit_decode_cost(n)
        + _adder(w)                     # the ONE addition replacing the mult
        + posit_encode_cost(n)
    )
    delay = (
        5 * math.log2(n)
        + 1.5 * math.log2(max(w, 2))    # CLA adder
        + 5 * math.log2(n)
    )
    return Cost(area, delay)


def float_mult(n, mant):
    m = mant + 1
    area = _array_mult(m) + _adder(11) + _adder(n)  # mult + exp add + round
    delay = 3 + 4 * math.log2(m) + math.log2(2 * m) + 3
    return Cost(area, delay)


FLOATS = {"float32": (32, 23), "float16": (16, 10), "bfloat16": (16, 7)}


def table():
    rows = []
    for n, es in [(8, 0), (16, 1), (16, 2), (32, 2)]:
        ex = exact_posit_mult(n, es)
        pl = plam_posit_mult(n, es)
        rows.append({
            "unit": f"posit<{n},{es}>",
            "exact_area": ex.area, "plam_area": pl.area,
            "area_red_%": 100 * (1 - pl.area / ex.area),
            "exact_power": ex.power, "plam_power": pl.power,
            "power_red_%": 100 * (1 - pl.power / ex.power),
            "exact_delay": ex.delay, "plam_delay": pl.delay,
            "delay_red_%": 100 * (1 - pl.delay / ex.delay),
        })
    for name, (n, mant) in FLOATS.items():
        f = float_mult(n, mant)
        rows.append({"unit": name, "exact_area": f.area, "plam_area": None,
                     "area_red_%": None, "exact_power": f.power, "plam_power": None,
                     "power_red_%": None, "exact_delay": f.delay, "plam_delay": None,
                     "delay_red_%": None})
    return rows


PAPER_REPORTED = {  # paper Sec. V, 32-bit vs FloPoCo-Posit [16]
    "area_red_%": 72.86, "power_red_%": 81.79, "delay_red_%": 17.01,
    "area_red_16b_%": 69.06, "power_red_16b_%": 63.63,
}


def main():
    rows = table()
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join("" if r[c] is None else (f"{r[c]:.1f}" if isinstance(r[c], float) else str(r[c])) for c in cols))
    r32 = next(r for r in rows if r["unit"] == "posit<32,2>")
    r16 = next(r for r in rows if r["unit"] == "posit<16,1>")
    print(f"\n# model 32-bit: area -{r32['area_red_%']:.1f}% power -{r32['power_red_%']:.1f}% "
          f"delay -{r32['delay_red_%']:.1f}%  (paper: -72.9%/-81.8%/-17.0%)")
    print(f"# model 16-bit: area -{r16['area_red_%']:.1f}% power -{r16['power_red_%']:.1f}% "
          "(paper: -69.1%/-63.6%)")


if __name__ == "__main__":
    main()
