"""Serving benchmark: continuous batching vs the static batcher.

Drives both engines over the same mixed-length, staggered-arrival
request stream (the traffic shape the ROADMAP's north star cares
about) and reports:

* tokens/sec (generated tokens over wall time, post-warmup);
* padding waste — the fraction of engine capacity spent on padding
  prompts to a common length plus slots idling while stragglers finish
  (static batching) vs bucket padding plus empty slots (continuous).

The static baseline pads every prompt to the stream's max length and
decodes everyone for max_new steps in lockstep; the paged engine
admits per step and retires early finishers, so mixed lengths stop
costing quadratic padding.

Reading the numbers: padding waste is the architectural win and shows
at any scale.  At toy CPU scale the static batcher can still win raw
wall-clock (its whole run is a handful of fused XLA calls, while
continuous batching pays a host round-trip per step); the reclaimed
capacity converts to throughput once model compute, not dispatch,
dominates a step — i.e. at real model sizes on real accelerators.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--requests 12]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.serving import (
    ContinuousBatchingEngine,
    Engine,
    PagedServeConfig,
    ServeConfig,
)

BASE = ModelConfig(
    name="serve-bench", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv=2, head_dim=32, d_ff=256, vocab=256,
    numerics=NumericsConfig(mode="f32"),
    act_dtype="float32", param_dtype="float32",
)


def make_stream(n_requests: int, seed: int = 0):
    """Mixed-length prompts with staggered arrivals (bursty Poisson-ish)."""
    rng = np.random.default_rng(seed)
    stream = []
    step = 0
    for _ in range(n_requests):
        plen = int(rng.integers(4, 48))
        max_new = int(rng.integers(4, 24))
        stream.append((rng.integers(0, 256, plen).tolist(), max_new, step))
        step += int(rng.integers(0, 3))  # 0-2 engine steps between arrivals
    return stream


def bench_static(params, stream):
    """Static batcher: one batch, padded to max prompt len, decoding
    max(max_new) steps for everyone; late arrivals wait for the batch."""
    eng = Engine(BASE, params)
    max_plen = max(len(p) for p, _, _ in stream)
    max_new = max(m for _, m, _ in stream)
    toks = np.zeros((len(stream), max_plen), np.int32)
    for i, (p, _, _) in enumerate(stream):
        toks[i, max_plen - len(p):] = p  # left-pad (right-aligned prompts)
    batch = {"tokens": jnp.asarray(toks)}
    scfg = ServeConfig(max_new_tokens=max_new)
    eng.generate(batch, scfg)  # warmup/compile
    t0 = time.perf_counter()
    out = eng.generate(batch, scfg)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0

    useful = sum(m for _, m, _ in stream)
    total_tok = out.shape[0] * out.shape[1]
    prompt_pad = sum(max_plen - len(p) for p, _, _ in stream)
    prompt_real = sum(len(p) for p, _, _ in stream)
    decode_waste = total_tok - useful
    spent = prompt_real + prompt_pad + total_tok
    return {
        "engine": "static",
        "wall_s": dt,
        "useful_tokens": useful,
        "tok_per_s": useful / dt,
        "padding_waste": (prompt_pad + decode_waste) / spent,
    }


def bench_continuous(params, stream, warmup: bool = True):
    from repro.serving import ServeStats

    pcfg = PagedServeConfig(block_size=8, num_blocks=256, max_slots=8,
                            max_seq_len=128)
    eng = ContinuousBatchingEngine(BASE, params=params, pcfg=pcfg)
    if warmup:  # compile prefill buckets + the decode step off the clock
        for p, m, _ in stream:
            eng.submit(p, max_new_tokens=m, arrival_step=0)
        eng.run()
        eng.stats = ServeStats()
    base_step = eng.current_step  # arrival steps are absolute
    for p, m, s in stream:
        eng.submit(p, max_new_tokens=m, arrival_step=base_step + s)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    useful = sum(len(v) for v in done.values())
    assert useful == sum(m for _, m, _ in stream), "engine dropped tokens"
    return {
        "engine": "continuous",
        "wall_s": dt,
        "useful_tokens": useful,
        "tok_per_s": useful / dt,
        "padding_waste": eng.stats.padding_waste(),
        "steps": eng.stats.steps,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    stream = make_stream(args.requests, args.seed)
    print(f"stream: {len(stream)} requests, prompt lens "
          f"{sorted(len(p) for p, _, _ in stream)}")
    params = Engine(BASE, key=jax.random.PRNGKey(0)).params

    rows = [bench_static(params, stream), bench_continuous(params, stream)]
    print(f"\n{'engine':<12}{'tok/s':>10}{'wall_s':>10}{'useful':>8}"
          f"{'pad_waste':>11}")
    for r in rows:
        print(f"{r['engine']:<12}{r['tok_per_s']:>10.1f}{r['wall_s']:>10.3f}"
              f"{r['useful_tokens']:>8}{r['padding_waste']:>11.1%}")
    s, c = rows
    print(f"\npadding waste: static {s['padding_waste']:.1%} -> "
          f"continuous {c['padding_waste']:.1%}")


if __name__ == "__main__":
    main()
