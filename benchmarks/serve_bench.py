"""Serving benchmark: continuous batching vs the static batcher.

Drives the engines over the same mixed-length, staggered-arrival
request stream (the traffic shape the ROADMAP's north star cares
about) and reports, per engine configuration:

* tokens/sec (generated tokens over wall time, post-warmup);
* p50 / p95 per-step latency — both engines now keep per-step
  wall-clock in ``ServeStats``, so the comparison needs no guards;
  chunked prefill exists precisely to pull the p95 down under mixed
  traffic (a long prompt costs many bounded steps, not one huge one);
* padding waste — capacity spent padding prompts plus slots idling.

The continuous engine runs a small configuration matrix: tp=1 vs
tp=<--tp> (when enough devices exist) crossed with unchunked vs
chunked prefill, plus speculative-decoding rows (``--spec-k``, with
acceptance rate and committed tokens per verify step), and asserts
every configuration generates EXACTLY the same tokens — the greedy
token-identity bar that CI's bench-smoke job re-checks on every push.
A separate OVERLOAD scenario (arrival rate > pool capacity) compares
preemption off vs "recompute": short-request p95 completion latency in
engine steps, eviction/resume counts, resume latency and the
deterministic deadline-miss rate — asserting that preemption never
changes a completed request's tokens.
A PREFIX-CACHE scenario (shared system prompt, wave of requests behind
it) compares prefix_cache off vs on: hit rate, prefill tokens/MACs
saved and TTFT p50/p95 in deterministic engine steps — asserting the
wave saves >50% of its prefill tokens, TTFT p95 improves, and greedy
tokens are identical either way.
The bench model serves in plam_sim numerics (the paper's approximate
multiplier), whose per-matmul quantization also keeps greedy argmax
invariant to TP reduction-order float noise.

Reading the numbers: padding waste is the architectural win and shows
at any scale.  At toy CPU scale the static batcher can still win raw
wall-clock (its whole run is a handful of fused XLA calls, while
continuous batching pays a host round-trip per step) and tp=2 on a
forced CPU "mesh" pays collectives for no real parallel compute; the
reclaimed capacity converts to throughput once model compute, not
dispatch, dominates a step — i.e. at real model sizes on real
accelerators.

Run:
  PYTHONPATH=src python benchmarks/serve_bench.py [--requests 12]
  PYTHONPATH=src python benchmarks/serve_bench.py \
      --tp 2 --prefill-chunk 16 --spec-k 4 --force-host-devices 8 \
      --json BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import os
import time


def make_stream(n_requests: int, seed: int = 0):
    """Mixed-length prompts with staggered arrivals (bursty Poisson-ish)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    stream = []
    step = 0
    for _ in range(n_requests):
        plen = int(rng.integers(4, 48))
        max_new = int(rng.integers(4, 24))
        stream.append((rng.integers(0, 256, plen).tolist(), max_new, step))
        step += int(rng.integers(0, 3))  # 0-2 engine steps between arrivals
    return stream


def make_overload_stream(seed: int = 0):
    """Arrival rate > capacity: long low-priority requests saturating
    the pool with short high-priority requests arriving behind them.
    Prompt lengths are drawn from two fixed buckets (32 and 8) so the
    overload rows stay to a handful of prefill compiles.  Returns
    (prompt, max_new, arrival_step, priority, deadline_steps) tuples,
    arrival-ordered; half the shorts carry a step-count deadline."""
    import numpy as np

    rng = np.random.default_rng(seed)
    entries = []
    for i in range(4):  # the saturating background
        entries.append((rng.integers(0, 256, 32).tolist(), 16, i, 0, None))
    for j in range(6):  # the latency-sensitive foreground
        entries.append((rng.integers(0, 256, 8).tolist(), 6, 1 + j, 1,
                        60.0 if j % 2 else None))
    return sorted(entries, key=lambda e: e[2])


def make_prefix_stream(seed: int = 0):
    """Shared-system-prompt traffic: one early request publishes the
    48-token system prompt (six full blocks at the bench block size 8),
    then a wave of requests reuses it with short unique tails.  The
    wave arrives after the first request's chunked prefill completes —
    block hashes are registered at prefill completion, so arrivals
    before that point would reserve their own blocks and miss."""
    import numpy as np

    rng = np.random.default_rng(seed)
    system = rng.integers(0, 256, 48).tolist()
    entries = [(system + rng.integers(0, 256, 4).tolist(), 8, 0)]
    for j in range(6):
        tail = rng.integers(0, 256, 4 + j).tolist()
        entries.append((system + tail, 8, 8 + j))
    return entries


def bench_prefix_cache(base_cfg, params, *, prefix_cache, seed=0):
    """Shared-system-prompt scenario, cache off vs on.  TTFT is
    measured in engine steps on the injected step-counting clock
    (wall-clock at toy CPU scale is compile noise): per wave request,
    queue + prefill steps from the trace breakdown.  With the cache on,
    the 48-token system prompt is six block hits, so the suffix prefill
    is one 16-wide chunk instead of four — the TTFT win is structural,
    not a measurement artifact.  MAC savings price the skipped prefill
    tokens at the model's per-token forward MACs (mode-resolved, i.e.
    the PLAM-approximate-multiplier work the paper counts)."""
    import numpy as np

    from repro.serving import ContinuousBatchingEngine, PagedServeConfig
    from repro.serving.observability import macs_per_token_by_mode

    stream = make_prefix_stream(seed)
    box = {}
    pcfg = PagedServeConfig(
        block_size=8, num_blocks=64, max_slots=4, max_seq_len=96,
        prefill_chunk=16, prefix_cache=prefix_cache,
        clock=lambda: float(box["eng"].current_step) if box else 0.0)
    eng = ContinuousBatchingEngine(base_cfg, params=params, pcfg=pcfg)
    box["eng"] = eng
    reqs = [eng.submit(p, max_new_tokens=m, arrival_step=s)
            for p, m, s in stream]
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    assert len(done) == len(reqs), "prefix-cache bench dropped a request"
    eng.trace.validate()

    ttft = []
    for r in reqs[1:]:  # the wave; entry 0 warms the cache
        bd = eng.trace.breakdown(r.rid)
        ttft.append(bd.queue_s + bd.prefill_s)
    al = eng.allocator
    prompt_tokens = sum(len(p) for p, _, _ in stream)
    macs_per_tok = sum(macs_per_token_by_mode(base_cfg).values())
    return {
        "engine": "prefix",
        "prefix_cache": prefix_cache,
        "wall_s": dt,
        "steps": eng.stats.steps,
        "prefix_hit_rate": al.hits / max(al.hits + al.misses, 1),
        "prefill_tokens_saved": al.tokens_saved,
        "prefill_tokens_saved_frac": al.tokens_saved / prompt_tokens,
        "prefill_macs_saved": al.tokens_saved * macs_per_tok,
        "prefix_evictions": al.evictions,
        "cow_copies": al.cow_copies,
        "ttft_p50_steps": float(np.quantile(np.asarray(ttft), 0.50)),
        "ttft_p95_steps": float(np.quantile(np.asarray(ttft), 0.95)),
        "tokens": {r.rid: list(done[r.rid]) for r in reqs},
    }


def bench_overload(base_cfg, params, *, preemption, seed=0,
                   trace_out=None, metrics_out=None):
    """Overload scenario: the pool holds ~2 of the 4 concurrent long
    requests, so the shorts must either queue behind them (FCFS,
    preemption="off") or evict them (priority victims under
    "recompute").  The metric that separates the regimes is the SHORT
    requests' completion latency in engine steps — wall-clock would
    mostly measure CPU compile noise.  Deadlines tick on an injected
    step-counting clock, so the miss rate is deterministic — and so are
    the per-request queue/prefill/decode/parked breakdowns the trace
    derives (clock units are engine steps here, not seconds).
    ``trace_out`` / ``metrics_out`` write the run's trace (JSON-lines)
    and Prometheus snapshot — the artifacts CI uploads and
    schema-checks."""
    import numpy as np

    from repro.serving import ContinuousBatchingEngine, PagedServeConfig

    stream = make_overload_stream(seed)
    box = {}
    pcfg = PagedServeConfig(
        block_size=8, num_blocks=16, max_slots=4, max_seq_len=64,
        preemption=preemption,
        clock=lambda: float(box["eng"].current_step) if box else 0.0)
    eng = ContinuousBatchingEngine(base_cfg, params=params, pcfg=pcfg)
    box["eng"] = eng
    reqs = []
    for p, m, s, prio, dl in stream:
        reqs.append(eng.submit(p, max_new_tokens=m, arrival_step=s,
                               priority=prio, deadline_s=dl))
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0

    from repro.serving import RequestState

    eng.trace.validate()
    shorts = [r for r, e in zip(reqs, stream) if e[3] > 0]
    finished_shorts = [r for r in shorts if r.state is RequestState.FINISHED]
    short_lat = [r.finished_step - r.arrival_step for r in finished_shorts]
    with_deadline = [r for r in reqs if r.deadline_s is not None]
    # the injected clock counts engine steps, so these breakdowns are
    # deterministic: where each short request's lifetime went, in steps
    short_breakdowns = {}
    for r in shorts:
        bd = eng.trace.breakdown(r.rid)
        short_breakdowns[r.rid] = {
            "queue_steps": bd.queue_s, "prefill_steps": bd.prefill_s,
            "decode_steps": bd.decode_s, "parked_steps": bd.parked_s,
            "total_steps": bd.total_s, "terminal": bd.terminal,
        }
    if trace_out:
        eng.trace.to_jsonl(trace_out)
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(eng.metrics.to_prometheus_text())
    return {
        "engine": "overload",
        "preemption": preemption,
        "wall_s": dt,
        "steps": eng.stats.steps,
        "short_p95_latency_steps": (
            float(np.quantile(np.asarray(short_lat), 0.95))
            if short_lat else float("nan")),
        "short_breakdowns": short_breakdowns,
        "preemptions": int(eng.metrics.value("serve_preemptions_total")),
        "resumes": int(eng.metrics.value("serve_resumes_total")),
        "resume_latency_steps_mean": (
            float(np.mean(eng.stats.resume_latency_steps))
            if eng.stats.resume_latency_steps else 0.0),
        "deadline_miss_rate": (
            eng.metrics.value("serve_deadline_cancelled_total")
            / len(with_deadline) if with_deadline else 0.0),
        "tokens": {r.rid: list(r.output) for r in reqs
                   if r.state is RequestState.FINISHED},
    }


def bench_static(base_cfg, params, stream):
    """Static batcher: one batch, padded to max prompt len, decoding
    max(max_new) steps for everyone; late arrivals wait for the batch."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.serving import Engine, ServeConfig

    eng = Engine(base_cfg, params)
    max_plen = max(len(p) for p, _, _ in stream)
    max_new = max(m for _, m, _ in stream)
    toks = np.zeros((len(stream), max_plen), np.int32)
    for i, (p, _, _) in enumerate(stream):
        toks[i, max_plen - len(p):] = p  # left-pad (right-aligned prompts)
    batch = {"tokens": jnp.asarray(toks)}
    # time_steps: sync per decode step so p50/p95 are real wall latency
    scfg = ServeConfig(max_new_tokens=max_new, time_steps=True)
    eng.generate(batch, scfg)  # warmup/compile
    t0 = time.perf_counter()
    out = eng.generate(batch, scfg)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0

    useful = sum(m for _, m, _ in stream)
    total_tok = out.shape[0] * out.shape[1]
    prompt_pad = sum(max_plen - len(p) for p, _, _ in stream)
    prompt_real = sum(len(p) for p, _, _ in stream)
    decode_waste = total_tok - useful
    spent = prompt_real + prompt_pad + total_tok
    return {
        "engine": "static",
        "tp": 1,
        "prefill_chunk": 0,
        "wall_s": dt,
        "useful_tokens": useful,
        "tok_per_s": useful / dt,
        "p50_step_ms": eng.stats.latency_p50() * 1e3,
        "p95_step_ms": eng.stats.latency_p95() * 1e3,
        "padding_waste": (prompt_pad + decode_waste) / spent,
    }


def bench_continuous(base_cfg, params, stream, *, tp=1, prefill_chunk=0,
                     spec_k=0, warmup=True, trace=True):
    """One continuous-engine configuration.  Post-redesign, everything
    this reports is read from the engine's MetricsRegistry (the same
    names a Prometheus scrape would see) rather than ServeStats fields;
    per-request submit->first-token / submit->finish percentiles come
    from the trace.  ``trace=False`` measures the engine with recording
    disabled — the pair of runs is the trace-overhead check."""
    from repro.serving import ContinuousBatchingEngine, PagedServeConfig, ServeStats

    pcfg = PagedServeConfig(block_size=8, num_blocks=256, max_slots=8,
                            max_seq_len=128, tp=tp, prefill_chunk=prefill_chunk,
                            spec_k=spec_k, trace=trace)
    eng = ContinuousBatchingEngine(base_cfg, params=params, pcfg=pcfg)
    if warmup:  # compile prefill buckets/chunks + the decode step off the clock
        for p, m, _ in stream:
            eng.submit(p, max_new_tokens=m, arrival_step=0)
        eng.run()
        eng.stats = ServeStats()
        if eng.trace is not None:
            eng.trace.clear()
    base_step = eng.current_step  # arrival steps are absolute
    reqs = []
    for p, m, s in stream:
        reqs.append(eng.submit(p, max_new_tokens=m, arrival_step=base_step + s))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    useful = sum(len(v) for v in done.values())
    assert useful == sum(m for _, m, _ in stream), "engine dropped tokens"
    step_hist = eng.metrics.histogram("serve_step_latency_seconds")
    row = {
        "engine": "continuous",
        "tp": tp,
        "prefill_chunk": prefill_chunk,
        "spec_k": spec_k,
        "trace": trace,
        "wall_s": dt,
        "useful_tokens": useful,
        "tok_per_s": useful / dt,
        "p50_step_ms": step_hist.quantile(0.50) * 1e3,
        "p95_step_ms": step_hist.quantile(0.95) * 1e3,
        "padding_waste": eng.metrics.value("serve_padding_waste"),
        "steps": int(eng.metrics.value("serve_steps_total")),
        "acceptance_rate": eng.metrics.value("serve_spec_acceptance_rate"),
        "tokens_per_verify_step": eng.metrics.value(
            "serve_tokens_per_verify_step"),
        "tokens": [done[r.rid] for r in reqs],
    }
    if eng.trace is not None:
        eng.trace.validate()
        summary = eng.trace.latency_summary()
        row.update({
            "req_ttft_p50_s": summary["first_token_p50_s"],
            "req_ttft_p95_s": summary["first_token_p95_s"],
            "req_total_p50_s": summary["total_p50_s"],
            "req_total_p95_s": summary["total_p95_s"],
        })
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=2,
                    help="sharded configuration to benchmark against tp=1 "
                         "(skipped when fewer devices exist)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked-prefill width for the chunked rows "
                         "(a multiple of the bench block size, 8)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative-decoding depth for the spec rows "
                         "(0 = skip them); spec rows join the cross-config "
                         "token-identity assertion")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write results (tokens/s, p95 step latency, "
                         "padding-waste %%) as JSON, e.g. BENCH_serving.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the overload-recompute run's trace events as "
                         "JSON-lines (the artifact CI schema-checks)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the overload-recompute run's Prometheus text "
                         "snapshot (the artifact CI schema-checks)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="force N CPU devices via XLA_FLAGS (set before jax "
                         "initializes; how CI gets a tp-capable host)")
    args = ap.parse_args()

    if args.force_host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.force_host_devices}"
        )

    import jax

    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.serving import Engine

    # PLAM-mode numerics, not f32: besides being the paper's serving
    # story, the per-matmul quantization snaps logits onto a shared
    # grid, which makes greedy argmax invariant to the reduction-order
    # float noise TP introduces (f32 near-ties can flip a token between
    # tp=1 and tp=2 even though both engines are correct to ~1e-3)
    base_cfg = ModelConfig(
        name="serve-bench", family="dense", n_layers=4, d_model=128, n_heads=4,
        n_kv=2, head_dim=32, d_ff=256, vocab=256,
        numerics=NumericsConfig(mode="plam_sim", n=16, es=1),
        act_dtype="float32", param_dtype="float32",
    )

    stream = make_stream(args.requests, args.seed)
    print(f"stream: {len(stream)} requests, prompt lens "
          f"{sorted(len(p) for p, _, _ in stream)}")
    params = Engine(base_cfg, key=jax.random.PRNGKey(0)).params

    matrix = [(1, 0, 0), (1, args.prefill_chunk, 0)]
    if args.spec_k:
        matrix += [(1, 0, args.spec_k), (1, args.prefill_chunk, args.spec_k)]
    if args.tp > 1:
        if len(jax.devices()) >= args.tp:
            matrix += [(args.tp, 0, 0), (args.tp, args.prefill_chunk, 0)]
            if args.spec_k:
                matrix += [(args.tp, 0, args.spec_k),
                           (args.tp, args.prefill_chunk, args.spec_k)]
        else:
            print(f"[skip] tp={args.tp}: only {len(jax.devices())} device(s); "
                  f"rerun with --force-host-devices {max(8, args.tp)}")

    rows = [bench_static(base_cfg, params, stream)]
    for tp, chunk, spec_k in matrix:
        rows.append(bench_continuous(base_cfg, params, stream,
                                     tp=tp, prefill_chunk=chunk,
                                     spec_k=spec_k))

    # trace-overhead check: the same tp=1 unchunked configuration with
    # recording disabled.  Tracing is on by default, so the delta must
    # stay well under the 5% tok/s budget at real step costs — at toy
    # CPU scale both runs are dispatch-noise-dominated, so the recorded
    # number is the honest measurement, not a pass/fail gate.
    off_row = bench_continuous(base_cfg, params, stream, trace=False)
    on_row = next(r for r in rows
                  if r["engine"] == "continuous" and r["tp"] == 1
                  and r["prefill_chunk"] == 0 and r["spec_k"] == 0)
    assert off_row["tokens"] == on_row["tokens"], (
        "disabling tracing changed the generated tokens")
    off_row.pop("tokens")
    trace_overhead = {
        "tok_per_s_trace_on": on_row["tok_per_s"],
        "tok_per_s_trace_off": off_row["tok_per_s"],
        "overhead_frac": 1.0 - on_row["tok_per_s"] / off_row["tok_per_s"],
    }

    # greedy decode must be configuration-invariant: every continuous
    # config — including the speculative ones — generates the same
    # per-request tokens (CI fails here first)
    token_sets = [r.pop("tokens") for r in rows if r["engine"] == "continuous"]
    token_identical = all(t == token_sets[0] for t in token_sets[1:])
    assert token_identical, (
        "continuous engine configurations diverged under greedy decode "
        "(tp/chunked/spec must be token-identical to tp=1 unchunked)")

    # overload scenario: arrival rate > pool capacity, preemption off vs
    # on.  Preemption joins the identity bar: every request that ran to
    # completion in both regimes emitted the same tokens, evictions and
    # recompute-resumes included (deadline-cancelled stragglers differ
    # by construction — a cancelled stream is a shorter stream).
    overload_rows = [
        bench_overload(base_cfg, params, preemption="off", seed=args.seed),
        bench_overload(base_cfg, params, preemption="recompute",
                       seed=args.seed, trace_out=args.trace_out,
                       metrics_out=args.metrics_out),
    ]
    off_toks, on_toks = [r.pop("tokens") for r in overload_rows]
    both = sorted(set(off_toks) & set(on_toks))
    assert both, "overload runs finished no common requests"
    assert all(off_toks[rid] == on_toks[rid] for rid in both), (
        "preemption changed a completed request's tokens under overload")

    # shared-system-prompt scenario: the prefix cache must leave greedy
    # tokens untouched while skipping most of the wave's prefill
    prefix_rows = [
        bench_prefix_cache(base_cfg, params, prefix_cache=False,
                           seed=args.seed),
        bench_prefix_cache(base_cfg, params, prefix_cache=True,
                           seed=args.seed),
    ]
    pc_off, pc_on = prefix_rows
    assert pc_off.pop("tokens") == pc_on.pop("tokens"), (
        "prefix cache changed a request's greedy tokens")
    assert pc_on["prefill_tokens_saved_frac"] > 0.5, (
        "shared-system-prompt wave saved less than half its prefill "
        f"tokens: {pc_on['prefill_tokens_saved_frac']:.1%}")
    assert pc_on["ttft_p95_steps"] < pc_off["ttft_p95_steps"], (
        "prefix cache did not improve TTFT p95 "
        f"({pc_on['ttft_p95_steps']} vs {pc_off['ttft_p95_steps']} steps)")
    ttft_p95_speedup = pc_off["ttft_p95_steps"] / pc_on["ttft_p95_steps"]

    hdr = (f"{'engine':<12}{'tp':>3}{'chunk':>6}{'spec':>5}{'tok/s':>10}"
           f"{'wall_s':>9}{'p50_ms':>8}{'p95_ms':>8}{'pad_waste':>11}"
           f"{'accept':>8}{'tok/vfy':>8}")
    print("\n" + hdr)
    for r in rows:
        spec_k = r.get("spec_k", 0)
        accept = f"{r['acceptance_rate']:>8.1%}" if spec_k else f"{'-':>8}"
        tpv = (f"{r['tokens_per_verify_step']:>8.2f}" if spec_k
               else f"{'-':>8}")
        print(f"{r['engine']:<12}{r['tp']:>3}{r['prefill_chunk']:>6}"
              f"{spec_k:>5}{r['tok_per_s']:>10.1f}{r['wall_s']:>9.3f}"
              f"{r['p50_step_ms']:>8.2f}{r['p95_step_ms']:>8.2f}"
              f"{r['padding_waste']:>11.1%}{accept}{tpv}")
    s, c = rows[0], rows[1]
    print(f"\npadding waste: static {s['padding_waste']:.1%} -> "
          f"continuous {c['padding_waste']:.1%}; token_identical across "
          f"{len(token_sets)} continuous configs: {token_identical}")
    print(f"trace overhead (tp=1 unchunked): "
          f"{trace_overhead['tok_per_s_trace_on']:.1f} tok/s traced vs "
          f"{trace_overhead['tok_per_s_trace_off']:.1f} untraced "
          f"({trace_overhead['overhead_frac']:+.1%})")
    print(f"per-request latency (tp=1 unchunked, traced): "
          f"ttft p50={c['req_ttft_p50_s'] * 1e3:.1f}ms "
          f"p95={c['req_ttft_p95_s'] * 1e3:.1f}ms; total "
          f"p50={c['req_total_p50_s'] * 1e3:.1f}ms "
          f"p95={c['req_total_p95_s'] * 1e3:.1f}ms")

    print(f"\n{'overload':<12}{'preempt':>10}{'short_p95':>11}{'steps':>7}"
          f"{'evict':>7}{'resume':>8}{'rsm_lat':>9}{'dl_miss':>9}")
    for r in overload_rows:
        print(f"{r['engine']:<12}{r['preemption']:>10}"
              f"{r['short_p95_latency_steps']:>11.1f}{r['steps']:>7}"
              f"{r['preemptions']:>7}{r['resumes']:>8}"
              f"{r['resume_latency_steps_mean']:>9.1f}"
              f"{r['deadline_miss_rate']:>9.1%}")

    print(f"\n{'prefix':<12}{'cache':>7}{'hit_rate':>10}{'saved':>7}"
          f"{'saved_frac':>12}{'ttft_p50':>10}{'ttft_p95':>10}")
    for r in prefix_rows:
        print(f"{r['engine']:<12}{('on' if r['prefix_cache'] else 'off'):>7}"
              f"{r['prefix_hit_rate']:>10.1%}{r['prefill_tokens_saved']:>7}"
              f"{r['prefill_tokens_saved_frac']:>12.1%}"
              f"{r['ttft_p50_steps']:>10.1f}{r['ttft_p95_steps']:>10.1f}")
    print(f"prefix cache: ttft p95 {pc_off['ttft_p95_steps']:.1f} -> "
          f"{pc_on['ttft_p95_steps']:.1f} steps "
          f"({ttft_p95_speedup:.1f}x), prefill MACs saved "
          f"{pc_on['prefill_macs_saved']:.3e}")

    if args.json:
        payload = {
            "bench": "serving",
            "requests": args.requests,
            "seed": args.seed,
            "devices": len(jax.devices()),
            "token_identical": token_identical,
            "rows": rows,
            "trace_overhead": trace_overhead,
            "overload": overload_rows,
            "prefix_cache": {
                "off": pc_off,
                "on": pc_on,
                "ttft_p95_speedup": ttft_p95_speedup,
            },
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
