"""End-to-end driver #2: train a ~100M-param LM for a few hundred steps
in posit16-quantized numerics with checkpoint/restart fault tolerance.

Demonstrates the full substrate: model zoo config (reduced yi-6b
family), synthetic deterministic data, AdamW, checkpointing, a
simulated node failure at step 120, and automatic recovery.

Run:  PYTHONPATH=src python examples/train_lm_posit.py [--quick] [--steps N]
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config
from repro.core.modes import NumericsConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.optim.optimizers import OptConfig
from repro.train.loop import FailureInjector, TrainConfig, run

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

# ~100M params: yi-6b family, shrunk
cfg = dataclasses.replace(
    get_config("yi-6b").reduced(),
    n_layers=4 if args.quick else 8,
    d_model=256 if args.quick else 512,
    n_heads=8, n_kv=4, head_dim=64,
    d_ff=1024 if args.quick else 2048,
    vocab=2048 if args.quick else 32768,
    param_dtype="float32", act_dtype="float32",
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
)
api = build(cfg)
n_params = sum(x.size for x in jax.tree.leaves(jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))))
print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M numerics={cfg.numerics.mode}")

steps = args.steps or (60 if args.quick else 300)
dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=128 if args.quick else 256,
                  global_batch=8)

with tempfile.TemporaryDirectory() as ckdir:
    tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=1e-3),
                       ckpt_dir=ckdir, ckpt_every=50, log_every=10)
    params, state, info = run(
        loss_fn=api.train_loss,
        init_params_fn=lambda: api.init(jax.random.PRNGKey(0)),
        batch_fn=lambda s: lm_batch(dcfg, s),
        tcfg=tcfg,
        num_steps=steps,
        failure=FailureInjector([min(120, steps - 10)]),  # simulated crash
    )

print(f"\nrestarts (injected failures recovered): {info['restarts']}")
print("loss curve (step, loss):")
for s, l in info["history"]:
    print(f"  {s:5d}  {l:.4f}")
first, last = info["history"][0][1], info["history"][-1][1]
print(f"\nloss {first:.3f} -> {last:.3f} ({'LEARNING' if last < first else 'check config'})")
