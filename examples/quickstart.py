"""Quickstart: the paper's multiplier in five steps.

1. Encode floats as Posit<16,1> patterns.
2. Multiply exactly and with PLAM; see the bounded approximation error.
3. Run a PLAM matrix multiplication (the Pallas kernel, interpret mode).
4. Quantize a tensor onto the posit grid (training-time fake-quant).
5. Drop PLAM into a model via the numerics config.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.numerics import P16, decode, encode, exact_mul, plam_mul, quantize
from repro.kernels import plam_matmul_bits
from repro.core.modes import NumericsConfig, nmatmul

# 1. encode / decode -------------------------------------------------------
xs = jnp.asarray(np.float32([3.14159, -0.001, 42.0, 0.5]))
bits = encode(xs, P16)
print("floats:", xs)
print("posit16 patterns:", [hex(int(b) & 0xFFFF) for b in bits])
print("decoded:", decode(bits, P16))

# 2. exact vs PLAM multiplication ------------------------------------------
a, b = encode(jnp.float32(1.5), P16), encode(jnp.float32(1.5), P16)
exact = decode(exact_mul(a, b, P16), P16)
plam = decode(plam_mul(a, b, P16), P16)
print(f"\n1.5 * 1.5 exact={float(exact)} plam={float(plam)} "
      f"(rel err {float((exact - plam) / exact) * 100:.2f}%, bound 11.1%)")

# 3. PLAM matmul kernel ----------------------------------------------------
rng = np.random.default_rng(0)
A = encode(jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)), P16)
B = encode(jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)), P16)
C = plam_matmul_bits(A, B, P16)
print(f"\nPLAM matmul 64x64x64 -> mean |C| = {float(jnp.mean(jnp.abs(C))):.4f}")

# 4. posit fake-quant (straight-through gradients) -------------------------
x = jnp.linspace(-2, 2, 9)
print("\nquantize onto posit16 grid:", quantize(x, P16))
g = jax.grad(lambda v: jnp.sum(quantize(v, P16)))(x)
print("STE gradient (identity):", g)

# 5. numerics-aware matmul in a model --------------------------------------
for mode in ["f32", "posit_quant", "plam_sim"]:
    ncfg = NumericsConfig(mode=mode, n=16, es=1)
    y = nmatmul(jnp.ones((2, 8)), jnp.full((8, 3), 0.3), ncfg)
    print(f"nmatmul[{mode:12s}] -> {np.asarray(y[0])}")
