"""End-to-end driver #3: serve a small LM with batched requests under
THREE numerics modes, including bit-exact PLAM inference — the paper's
deployment scenario (approximate multipliers at inference time only).

Prints per-mode generations and their agreement rate: the PLAM output
should match the exact-posit output almost always (bounded 11.1%
per-product error is far below the logit decision margin).

Run:  PYTHONPATH=src python examples/serve_lm_plam.py
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.optim.optimizers import OptConfig, init_state
from repro.serving.engine import Engine, ServeConfig
from repro.train.loop import TrainConfig, make_train_step

BASE = ModelConfig(
    name="serve-demo", family="dense", n_layers=3, d_model=128, n_heads=4,
    n_kv=2, head_dim=32, d_ff=256, vocab=256,
    numerics=NumericsConfig(mode="f32"),
)

# quick train so generations are non-trivial
dcfg = DataConfig(seed=0, vocab=256, seq_len=64, global_batch=16)
api = build(BASE)
params = api.init(jax.random.PRNGKey(0))
tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
step = jax.jit(make_train_step(api.train_loss, tcfg))
state = init_state(tcfg.opt, params)
for i in range(80):
    params, state, m = step(params, state, lm_batch(dcfg, i))
print(f"trained toy LM to loss {float(m['loss']):.3f}")

rng = np.random.default_rng(7)
prompts = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 16)).astype(np.int32))}

outs = {}
for mode in ["f32", "posit_quant", "plam_sim"]:
    cfg = BASE.with_numerics(NumericsConfig(mode=mode, n=16, es=1))
    eng = Engine(cfg, params)
    outs[mode] = np.asarray(eng.generate(prompts, ServeConfig(max_new_tokens=12)))
    print(f"[{mode:12s}] batch0 tokens: {outs[mode][0].tolist()}")

agree_pq = (outs["posit_quant"] == outs["f32"]).mean()
agree_pl = (outs["plam_sim"] == outs["posit_quant"]).mean()
print(f"\nposit16-exact vs f32 token agreement : {agree_pq:.2%}")
print(f"PLAM vs posit16-exact token agreement: {agree_pl:.2%}  (paper: parity)")
