"""End-to-end driver #3: continuous-batching PLAM inference.

A stream of requests with mixed prompt lengths and staggered arrivals
is served by the paged-KV continuous-batching engine under THREE
numerics modes, including bit-exact PLAM — the paper's deployment
scenario (approximate multipliers at inference time only), now under
realistic traffic instead of one lockstep batch.

Prints per-mode generations and their agreement rate: the PLAM output
should match the exact-posit output almost always (bounded 11.1%
per-product error is far below the logit decision margin), and the
engine's padding-waste stats show what continuous batching buys.

Uses the redesigned serving API throughout: one ``ServeOptions``,
``build_engine`` picking the continuous engine for the dense family,
``submit()`` handles with per-request latency breakdowns, and the
metrics registry for the per-mode stats line.

Run:  PYTHONPATH=src python examples/serve_lm_plam.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.optim.optimizers import OptConfig, init_state
from repro.serving import ServeOptions, build_engine
from repro.train.loop import TrainConfig, make_train_step

BASE = ModelConfig(
    name="serve-demo", family="dense", n_layers=3, d_model=128, n_heads=4,
    n_kv=2, head_dim=32, d_ff=256, vocab=256,
    numerics=NumericsConfig(mode="f32"),
)

# quick train so generations are non-trivial
dcfg = DataConfig(seed=0, vocab=256, seq_len=64, global_batch=16)
api = build(BASE)
params = api.init(jax.random.PRNGKey(0))
tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
step = jax.jit(make_train_step(api.train_loss, tcfg))
state = init_state(tcfg.opt, params)
for i in range(80):
    params, state, m = step(params, state, lm_batch(dcfg, i))
print(f"trained toy LM to loss {float(m['loss']):.3f}")

# a staggered stream: 6 requests, mixed prompt lengths, arrivals spread
# over the first engine steps — the engine admits them mid-decode
rng = np.random.default_rng(7)
stream = []
for i in range(6):
    plen = int(rng.integers(6, 24))
    stream.append((rng.integers(0, 256, plen).tolist(), i))  # arrive at step i

opts = ServeOptions(max_new_tokens=12, block_size=8, num_blocks=64,
                    max_slots=3, max_seq_len=64)
outs = {}
for mode in ["f32", "posit_quant", "plam_sim"]:
    cfg = BASE.with_numerics(NumericsConfig(mode=mode, n=16, es=1))
    eng = build_engine(cfg, opts, params=params)  # dense -> continuous
    handles = [eng.submit(p, arrival_step=s, **opts.submit_kwargs())
               for p, s in stream]
    done = eng.run()
    outs[mode] = np.asarray([done[h.rid] for h in handles])
    bd = handles[0].breakdown()
    print(f"[{mode:12s}] request0 tokens: {outs[mode][0].tolist()}  "
          f"(steps={int(eng.metrics.value('serve_steps_total'))}, "
          f"pad_waste={eng.metrics.value('serve_padding_waste'):.1%}, "
          f"req0 ttft={bd.first_token_s * 1e3:.0f}ms "
          f"queue/prefill/decode="
          f"{bd.queue_s * 1e3:.0f}/{bd.prefill_s * 1e3:.0f}/"
          f"{bd.decode_s * 1e3:.0f}ms)")

agree_pq = (outs["posit_quant"] == outs["f32"]).mean()
agree_pl = (outs["plam_sim"] == outs["posit_quant"]).mean()
print(f"\nposit16-exact vs f32 token agreement : {agree_pq:.2%}")
print(f"PLAM vs posit16-exact token agreement: {agree_pl:.2%}  (paper: parity)")
