"""Posit/PLAM explorer: dynamic range, precision tapering, error heatmap.

A numerics playground for the paper's format:
  PYTHONPATH=src python examples/posit_explorer.py [n] [es]
"""
import sys

import numpy as np
import jax.numpy as jnp

from repro.numerics import PositSpec, decode, encode, plam_relative_error
from repro.numerics.golden import all_values

n = int(sys.argv[1]) if len(sys.argv) > 1 else 8
es = int(sys.argv[2]) if len(sys.argv) > 2 else 0
spec = PositSpec(n, es)

vals = np.asarray(all_values(n, es))
print(f"Posit<{n},{es}>: {len(vals)} positive values")
print(f"  minpos = {vals[0]:.3e}   maxpos = {vals[-1]:.3e}")
print(f"  dynamic range = {np.log10(vals[-1] / vals[0]):.1f} decades")

# precision tapering (the posit selling point: max precision near 1)
print("\nrelative spacing (ulp/value) by magnitude — tapered accuracy:")
for target in [1e-6, 1e-3, 0.1, 1.0, 10.0, 1e3, 1e6]:
    i = int(np.searchsorted(vals, target))
    if 0 < i < len(vals) - 1:
        ulp = (vals[i + 1] - vals[i]) / vals[i]
        print(f"  near {target:8.0e}: {ulp:.2e}")

# PLAM error heatmap over the fraction square (paper Fig. analog)
print("\nPLAM relative error over (fa, fb), eq. (24) — '.' <2%  '+' <6%  '#' <=11.1%:")
steps = 24
fa = np.linspace(0, 1, steps, endpoint=False)
a = encode(jnp.asarray((1 + fa).astype(np.float32)), spec)
err = np.asarray(plam_relative_error(a[:, None], a[None, :], spec))
for row in err[::2]:
    print("  " + "".join("#" if e > 0.06 else ("+" if e > 0.02 else ".") for e in row))
print(f"max = {err.max():.4f} (bound 1/9 = {1/9:.4f}) at fa=fb=0.5")
