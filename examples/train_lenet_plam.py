"""End-to-end driver #1 (paper's use case): train LeNet-5 in float32 on
a synthetic MNIST-stand-in, then run inference under exact Posit<16,1>
and PLAM — the Table II experiment, reproduced end to end.

Run:  PYTHONPATH=src python examples/train_lenet_plam.py [--quick]
"""
import sys

from repro.core.modes import NumericsConfig
from repro.data.synthetic import image_dataset
from repro.paper.models import accuracy, lenet5_apply, lenet5_init, train_classifier

quick = "--quick" in sys.argv
n = 1500 if quick else 4000
epochs = 3 if quick else 10

x, y = image_dataset(seed=0, n=n + 1000, hw=28, channels=1, n_classes=10)
xtr, ytr, xte, yte = x[:n], y[:n], x[n:], y[n:]

print(f"training LeNet-5 on {n} synthetic MNIST-like images ({epochs} epochs)...")
params = train_classifier(
    lambda k: lenet5_init(k, 1, 10, 28), lenet5_apply, xtr, ytr,
    epochs=epochs, lr=1e-3,
)

for name, ncfg in [
    ("float32", NumericsConfig(mode="f32")),
    ("posit16-exact", NumericsConfig(mode="posit_quant", n=16, es=1)),
    ("posit16-PLAM", NumericsConfig(mode="plam_sim", n=16, es=1)),
]:
    accs = accuracy(lenet5_apply, params, xte, yte, ncfg, topk=(1, 5))
    print(f"{name:14s} top-1 {accs[1]:.4f}  top-5 {accs[5]:.4f}")

print("\npaper claim: the three columns should be within noise of each other")
