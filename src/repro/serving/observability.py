"""Serving observability: structured tracing, metrics, profiling hooks.

Three cooperating pieces, all host-side and dependency-light (numpy
only; jax is imported lazily and only for the opt-in profiler
annotations):

* :class:`TraceRecorder` — typed per-request event stream.  The engine
  emits one :class:`TraceEvent` per lifecycle transition (``SUBMIT``,
  ``ADMIT``, ``PREFILL_CHUNK``, ``DECODE``, ``VERIFY``, ``GROW``,
  ``PREEMPT``, ``RESUME``, ``CANCEL``, ``DEADLINE``, ``FINISH``), each
  carrying the request id, the engine step, a monotonic timestamp from
  the engine's injectable clock and the block-pool occupancy at
  emission time.  Every event type has a payload schema
  (:data:`EVENT_SCHEMA`) checked at emission, so an exported trace is
  valid by construction.  Exports: JSON-lines (:meth:`TraceRecorder.
  to_jsonl`) and Chrome ``trace_event`` JSON viewable in Perfetto /
  ``chrome://tracing`` (:meth:`TraceRecorder.to_chrome_trace`).  The
  per-request latency breakdown (``queue_s`` / ``prefill_s`` /
  ``decode_s`` / ``parked_s``) is DERIVED from event timestamps by a
  telescoping walk (:meth:`TraceRecorder.breakdown`), so the four
  buckets sum to the submit->terminal wall time exactly — there are no
  hand-maintained per-phase counters to drift out of sync.

* :class:`MetricsRegistry` — counters / gauges / histograms with a
  Prometheus text exporter and periodic snapshot hooks.  Instruments
  may hold a stored value (``inc`` / ``set`` / ``observe``) or a
  *source* callable read at collection time; the engine wires its
  registry with sources over live state (``ServeStats`` fields, the
  allocator free list, per-numerics-mode MAC totals resolved through
  ``repro.core.policy``), which makes metric collection free on the
  hot path and immune to benchmark-style stats resets.

* :func:`phase_annotation` — an opt-in ``jax.profiler``
  TraceAnnotation context per engine phase, a no-op unless profiling
  is enabled, so engine phases show up as named spans in a jax
  profiler trace.

Run ``python -m repro.serving.observability trace.jsonl [--prom
metrics.prom]`` to schema-check an exported trace (CI does, on the
bench-smoke artifacts).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# typed events
# ---------------------------------------------------------------------------

#: Every event type the engine may emit, with the payload keys an event
#: of that type MUST carry (pool occupancy keys are added to every
#: event by the recorder itself).  ``emit`` rejects unknown types and
#: missing keys, so traces validate at the source, not in CI.
EVENT_SCHEMA: Dict[str, Tuple[str, ...]] = {
    "SUBMIT": ("prompt_len", "max_new"),
    "ADMIT": ("slot", "blocks", "cached_len"),
    "PREFILL_CHUNK": ("start", "tokens", "width", "done", "out_len"),
    "DECODE": ("new_tokens", "out_len"),
    "VERIFY": ("k", "accepted", "new_tokens", "out_len"),
    "GROW": ("new_blocks", "blocks"),
    "PREEMPT": ("blocks_freed", "preempt_count", "out_len"),
    "RESUME": ("slot", "blocks", "parked_steps"),
    "CANCEL": ("reason", "out_len"),
    "DEADLINE": ("deadline_s", "out_len"),
    "FINISH": ("out_len",),
}

EVENT_TYPES: Tuple[str, ...] = tuple(EVENT_SCHEMA)

#: Exactly one of these ends every request's event sequence.
TERMINAL_EVENTS: Tuple[str, ...] = ("FINISH", "CANCEL", "DEADLINE")

#: Occupancy keys the recorder stamps onto every event.
_OCCUPANCY_KEYS = ("free_blocks", "used_blocks")


class TraceInvariantError(AssertionError):
    """An event stream violated the request-lifecycle grammar."""


@dataclasses.dataclass
class TraceEvent:
    """One typed event: what happened, to which request, when."""

    etype: str
    rid: int
    step: int
    t: float
    payload: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        d = {"etype": self.etype, "rid": self.rid, "step": self.step, "t": self.t}
        d.update(self.payload)
        return d

    @staticmethod
    def from_dict(d: Dict[str, object]) -> "TraceEvent":
        payload = {
            k: v for k, v in d.items() if k not in ("etype", "rid", "step", "t")
        }
        return TraceEvent(
            etype=str(d["etype"]),
            rid=int(d["rid"]),
            step=int(d["step"]),
            t=float(d["t"]),
            payload=payload,
        )


def validate_event(ev: TraceEvent) -> None:
    """Schema check for one event: known type, required payload keys."""
    schema = EVENT_SCHEMA.get(ev.etype)
    if schema is None:
        raise TraceInvariantError(
            f"unknown event type {ev.etype!r}; expected one of {EVENT_TYPES}"
        )
    missing = [k for k in schema if k not in ev.payload]
    if missing:
        raise TraceInvariantError(
            f"{ev.etype} event for rid={ev.rid} is missing payload keys {missing}"
        )


def check_request_events(events: Sequence[TraceEvent]) -> None:
    """Well-formedness of ONE request's event sequence.

    Grammar: SUBMIT first (exactly once); at most one ADMIT, after
    SUBMIT; PREEMPT only while admitted and RESUME only while parked
    (so PREEMPT/RESUME strictly alternate); DECODE/VERIFY/GROW/
    PREFILL_CHUNK only while admitted; exactly one terminal event, in
    last position; timestamps non-decreasing.
    """
    if not events:
        raise TraceInvariantError("empty event sequence")
    rid = events[0].rid
    if events[0].etype != "SUBMIT":
        raise TraceInvariantError(f"rid={rid}: first event is {events[0].etype}")
    admitted = False  # currently holding a slot
    ever_admitted = False
    parked = False
    terminal = False
    last_t = events[0].t
    for ev in events[1:]:
        if ev.rid != rid:
            raise TraceInvariantError(f"rid mixup: {ev.rid} in rid={rid} stream")
        if terminal:
            raise TraceInvariantError(f"rid={rid}: event {ev.etype} after terminal")
        if ev.t < last_t:
            raise TraceInvariantError(
                f"rid={rid}: timestamps regress ({ev.t} < {last_t})"
            )
        last_t = ev.t
        if ev.etype == "SUBMIT":
            raise TraceInvariantError(f"rid={rid}: duplicate SUBMIT")
        elif ev.etype == "ADMIT":
            if ever_admitted:
                raise TraceInvariantError(
                    f"rid={rid}: second ADMIT (resumes emit RESUME)"
                )
            admitted = ever_admitted = True
        elif ev.etype == "RESUME":
            if not parked:
                raise TraceInvariantError(f"rid={rid}: RESUME without PREEMPT")
            parked, admitted = False, True
        elif ev.etype == "PREEMPT":
            if not admitted:
                raise TraceInvariantError(f"rid={rid}: PREEMPT while not admitted")
            admitted, parked = False, True
        elif ev.etype in ("PREFILL_CHUNK", "DECODE", "VERIFY", "GROW"):
            if not admitted:
                raise TraceInvariantError(
                    f"rid={rid}: {ev.etype} while not admitted"
                )
        elif ev.etype in TERMINAL_EVENTS:
            terminal = True
        else:  # pragma: no cover - emit() already rejects unknown types
            raise TraceInvariantError(f"rid={rid}: unknown event {ev.etype}")
    if not terminal:
        raise TraceInvariantError(f"rid={rid}: no terminal event")


# phase each event type transitions INTO, for the breakdown walk
_PHASE_AFTER = {
    "ADMIT": "prefill",
    "RESUME": "prefill",
    "PREEMPT": "parked",
}


@dataclasses.dataclass
class RequestBreakdown:
    """Where one request's wall time went, derived from its events.

    ``queue_s + prefill_s + decode_s + parked_s == total_s`` exactly
    (the derivation is a telescoping sum over event timestamps).
    ``first_token_s`` is submit -> first committed token; None when the
    request never emitted one.
    """

    rid: int
    queue_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    parked_s: float = 0.0
    total_s: float = 0.0
    first_token_s: Optional[float] = None
    terminal: str = ""

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def derive_breakdown(events: Sequence[TraceEvent]) -> RequestBreakdown:
    """Telescoping walk: attribute the delta between consecutive event
    timestamps to the phase the request was in, then switch phases on
    the transition events.  Sums to terminal.t - submit.t exactly."""
    check_request_events(events)
    bd = RequestBreakdown(rid=events[0].rid)
    buckets = {"queue": 0.0, "prefill": 0.0, "decode": 0.0, "parked": 0.0}
    phase = "queue"
    last_t = events[0].t
    for ev in events[1:]:
        buckets[phase] += ev.t - last_t
        last_t = ev.t
        if ev.etype in _PHASE_AFTER:
            phase = _PHASE_AFTER[ev.etype]
        elif ev.etype == "PREFILL_CHUNK" and ev.payload.get("done"):
            phase = "decode"
        if (
            bd.first_token_s is None
            and int(ev.payload.get("out_len", 0) or 0) >= 1
            and ev.etype in ("PREFILL_CHUNK", "DECODE", "VERIFY")
        ):
            bd.first_token_s = ev.t - events[0].t
        if ev.etype in TERMINAL_EVENTS:
            bd.terminal = ev.etype
    bd.queue_s = buckets["queue"]
    bd.prefill_s = buckets["prefill"]
    bd.decode_s = buckets["decode"]
    bd.parked_s = buckets["parked"]
    bd.total_s = events[-1].t - events[0].t
    return bd


class TraceRecorder:
    """Collects typed events; derives latency; exports traces.

    ``clock`` is the engine's injectable monotonic clock (tests use a
    fake); ``occupancy`` returns ``(free_blocks, used_blocks)`` and is
    sampled at every emission so each event carries the pool state the
    moment it happened.
    """

    def __init__(
        self,
        clock: Optional[Callable[[], float]] = None,
        occupancy: Optional[Callable[[], Tuple[int, int]]] = None,
    ):
        self.clock = clock if clock is not None else time.monotonic
        self.occupancy = occupancy
        self.events: List[TraceEvent] = []
        self._by_rid: Dict[int, List[TraceEvent]] = {}

    def __len__(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        """Drop recorded events (benchmarks clear after warmup)."""
        self.events.clear()
        self._by_rid.clear()

    def emit(self, etype: str, rid: int, step: int, **payload) -> TraceEvent:
        """Record one event NOW (timestamp from the clock), stamping
        pool occupancy and schema-checking the payload."""
        if self.occupancy is not None:
            free, used = self.occupancy()
            payload.setdefault("free_blocks", int(free))
            payload.setdefault("used_blocks", int(used))
        ev = TraceEvent(
            etype=etype, rid=rid, step=step, t=float(self.clock()), payload=payload
        )
        validate_event(ev)
        self.events.append(ev)
        self._by_rid.setdefault(rid, []).append(ev)
        return ev

    # -- per-request views -------------------------------------------------

    def request_events(self, rid: int) -> List[TraceEvent]:
        return list(self._by_rid.get(rid, ()))

    def rids(self) -> List[int]:
        return sorted(self._by_rid)

    def breakdown(self, rid: int) -> RequestBreakdown:
        return derive_breakdown(self._by_rid[rid])

    def validate(self) -> None:
        """Check every request's event sequence is well-formed.
        Requests without a terminal event yet are skipped (live)."""
        for rid, evs in self._by_rid.items():
            if evs and evs[-1].etype in TERMINAL_EVENTS:
                check_request_events(evs)

    def latency(self, rid: int) -> Tuple[Optional[float], Optional[float]]:
        """(submit -> first token, submit -> terminal) seconds; None
        for whichever has not happened yet."""
        evs = self._by_rid.get(rid, ())
        if not evs or evs[0].etype != "SUBMIT":
            return (None, None)
        t0 = evs[0].t
        first = None
        for ev in evs:
            if (
                ev.etype in ("PREFILL_CHUNK", "DECODE", "VERIFY")
                and int(ev.payload.get("out_len", 0) or 0) >= 1
            ):
                first = ev.t - t0
                break
        total = evs[-1].t - t0 if evs[-1].etype in TERMINAL_EVENTS else None
        return (first, total)

    def latency_summary(self) -> Dict[str, float]:
        """p50/p95 of submit->first-token and submit->finish across
        every request with a terminal event — the per-request numbers
        ``ServeStats`` never had (its resume_latency only counted
        parked time)."""
        firsts, totals = [], []
        for rid in self._by_rid:
            first, total = self.latency(rid)
            if total is not None:
                totals.append(total)
                if first is not None:
                    firsts.append(first)

        def q(xs: List[float], p: float) -> float:
            return float(np.quantile(np.asarray(xs), p)) if xs else 0.0

        return {
            "requests": float(len(totals)),
            "first_token_p50_s": q(firsts, 0.50),
            "first_token_p95_s": q(firsts, 0.95),
            "total_p50_s": q(totals, 0.50),
            "total_p95_s": q(totals, 0.95),
        }

    # -- exporters ---------------------------------------------------------

    def to_jsonl(self, path: str) -> None:
        """One JSON object per line, flat (payload keys inlined)."""
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev.to_dict()) + "\n")

    def to_chrome_trace(self, path: Optional[str] = None) -> Dict[str, object]:
        """Chrome ``trace_event`` JSON (open in Perfetto or
        ``chrome://tracing``): one track (tid) per request, an ``X``
        (complete) slice per contiguous phase segment, an ``i``
        (instant) mark per raw event.  Returns the trace dict; writes
        it to ``path`` when given."""
        if not self.events:
            trace: Dict[str, object] = {"traceEvents": [], "displayTimeUnit": "ms"}
            if path:
                with open(path, "w") as f:
                    json.dump(trace, f)
            return trace
        t0 = min(ev.t for ev in self.events)
        out: List[Dict[str, object]] = []
        for rid, evs in sorted(self._by_rid.items()):
            out.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": rid,
                    "args": {"name": f"request {rid}"},
                }
            )
            phase = "queue"
            seg_start = evs[0].t
            for ev in evs[1:]:
                next_phase = phase
                if ev.etype in _PHASE_AFTER:
                    next_phase = _PHASE_AFTER[ev.etype]
                elif ev.etype == "PREFILL_CHUNK" and ev.payload.get("done"):
                    next_phase = "decode"
                elif ev.etype in TERMINAL_EVENTS:
                    next_phase = ""
                if next_phase != phase:
                    if ev.t > seg_start:
                        out.append(
                            {
                                "name": phase,
                                "cat": "request",
                                "ph": "X",
                                "ts": (seg_start - t0) * 1e6,
                                "dur": (ev.t - seg_start) * 1e6,
                                "pid": 0,
                                "tid": rid,
                            }
                        )
                    phase, seg_start = next_phase, ev.t
            for ev in evs:
                out.append(
                    {
                        "name": ev.etype,
                        "cat": "event",
                        "ph": "i",
                        "s": "t",
                        "ts": (ev.t - t0) * 1e6,
                        "pid": 0,
                        "tid": ev.rid,
                        "args": {"step": ev.step, **ev.payload},
                    }
                )
        trace = {"traceEvents": out, "displayTimeUnit": "ms"}
        if path:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace


def load_jsonl(path: str) -> List[TraceEvent]:
    """Parse a JSON-lines trace back into events (schema-checked)."""
    events = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = TraceEvent.from_dict(json.loads(line))
            except (KeyError, ValueError, TypeError) as e:
                raise TraceInvariantError(f"{path}:{line_no}: {e}") from e
            validate_event(ev)
            events.append(ev)
    return events


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def _labels_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labels_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class _Instrument:
    """Shared machinery: a stored value or a live source callable."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.help = help
        self.labels = labels
        self._value = 0.0
        self._source: Optional[Callable[[], float]] = None

    def set_source(self, fn: Callable[[], float]) -> "_Instrument":
        """Read the value live at collection time instead of storing
        it — the engine's zero-hot-path-cost wiring."""
        self._source = fn
        return self

    @property
    def value(self) -> float:
        if self._source is not None:
            return float(self._source())
        return self._value


class Counter(_Instrument):
    kind = "counter"

    def inc(self, n: float = 1.0) -> None:
        assert self._source is None, "sourced counters are read-only"
        assert n >= 0, "counters only go up"
        self._value += n


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, v: float) -> None:
        assert self._source is None, "sourced gauges are read-only"
        self._value = float(v)


_DEFAULT_BUCKETS = (
    0.0005,
    0.001,
    0.0025,
    0.005,
    0.01,
    0.025,
    0.05,
    0.1,
    0.25,
    0.5,
    1.0,
    2.5,
    5.0,
)


class Histogram(_Instrument):
    """Sample-keeping histogram: exact quantiles for the façade, bucket
    counts for the Prometheus exporter.  ``set_source`` points it at a
    live sample list (e.g. ``ServeStats.step_latency_s``)."""

    kind = "histogram"

    def __init__(self, name, help, labels, buckets=_DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        self.buckets = tuple(buckets)
        self._samples: List[float] = []
        self._list_source: Optional[Callable[[], Sequence[float]]] = None

    def observe(self, v: float) -> None:
        assert self._list_source is None, "sourced histograms are read-only"
        self._samples.append(float(v))

    def set_source(self, fn: Callable[[], Sequence[float]]) -> "Histogram":
        self._list_source = fn
        return self

    @property
    def samples(self) -> List[float]:
        if self._list_source is not None:
            return list(self._list_source())
        return list(self._samples)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def value(self) -> float:  # sum, for snapshot symmetry
        return float(sum(self.samples))

    def quantile(self, q: float) -> float:
        xs = self.samples
        if not xs:
            return 0.0
        return float(np.quantile(np.asarray(xs), q))


class MetricsRegistry:
    """Get-or-create instruments keyed by (name, labels); Prometheus
    text exposition; periodic snapshot hooks driven by the engine's
    step counter (``tick``)."""

    def __init__(self):
        self._instruments: Dict[Tuple[str, Tuple], _Instrument] = {}
        self._hooks: List[Tuple[int, Callable[["MetricsRegistry"], None]]] = []

    def _get(self, cls, name: str, help: str, labels: Dict[str, str], **kw):
        key = (name, _labels_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, help, _labels_key(labels), **kw)
            self._instruments[key] = inst
        assert isinstance(inst, cls), f"{name} registered as {inst.kind}"
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(
        self, name: str, help: str = "", buckets=_DEFAULT_BUCKETS, **labels
    ) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def value(self, name: str, **labels) -> float:
        """Read one instrument's current value (sum, for histograms)."""
        return self._instruments[(name, _labels_key(labels))].value

    # -- periodic snapshots ------------------------------------------------

    def every(self, n_steps: int, fn: Callable[["MetricsRegistry"], None]) -> None:
        """Run ``fn(registry)`` every ``n_steps`` engine steps (the
        periodic snapshot hook; e.g. append ``snapshot()`` to a log)."""
        assert n_steps >= 1
        self._hooks.append((n_steps, fn))

    def tick(self, step: int) -> None:
        for n, fn in self._hooks:
            if step > 0 and step % n == 0:
                fn(self)

    # -- exporters ---------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Flat dict of every instrument's current value; histograms
        expand to count/sum/p50/p95."""
        out: Dict[str, object] = {}
        for (name, labels), inst in sorted(self._instruments.items()):
            full = name + _labels_str(labels)
            if isinstance(inst, Histogram):
                out[full] = {
                    "count": inst.count,
                    "sum": inst.value,
                    "p50": inst.quantile(0.50),
                    "p95": inst.quantile(0.95),
                }
            else:
                out[full] = inst.value
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (one scrape page)."""
        by_name: Dict[str, List[_Instrument]] = {}
        for (name, _), inst in sorted(self._instruments.items()):
            by_name.setdefault(name, []).append(inst)
        lines: List[str] = []
        for name, insts in by_name.items():
            first = insts[0]
            if first.help:
                lines.append(f"# HELP {name} {first.help}")
            lines.append(f"# TYPE {name} {first.kind}")
            for inst in insts:
                ls = _labels_str(inst.labels)
                if isinstance(inst, Histogram):
                    xs = inst.samples
                    acc = 0
                    for b in inst.buckets:
                        acc = sum(1 for x in xs if x <= b)
                        lb = dict(inst.labels)
                        lb["le"] = repr(b)
                        lines.append(
                            f"{name}_bucket{_labels_str(_labels_key(lb))} {acc}"
                        )
                    lb = dict(inst.labels)
                    lb["le"] = "+Inf"
                    lines.append(
                        f"{name}_bucket{_labels_str(_labels_key(lb))} {len(xs)}"
                    )
                    lines.append(f"{name}_sum{ls} {float(sum(xs))}")
                    lines.append(f"{name}_count{ls} {len(xs)}")
                else:
                    lines.append(f"{name}{ls} {inst.value}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# per-numerics-mode MAC attribution (core/policy site resolution)
# ---------------------------------------------------------------------------


def macs_per_token_by_mode(cfg) -> Dict[str, float]:
    """Per-token forward-pass MACs grouped by resolved numerics mode.

    Sites come from ``repro.numerics.calibrate.site_macs``; each site's
    mode is resolved through the model's numerics policy
    (``repro.core.policy.site_for``), per layer when layer-range rules
    exist, so a mixed policy reports exactly how many MACs run on the
    approximate multiplier vs exact posit vs float — the paper's
    cost-savings story as a serving metric.
    """
    from repro.core.policy import cfg_spec_str, site_for
    from repro.numerics.calibrate import site_macs

    out: Dict[str, float] = {}
    n_layers = getattr(cfg, "n_layers", 0) or 1
    layer_free = ("lm_head", "frontend", "hybrid.proj")
    for role, macs in site_macs(cfg).items():
        if role in layer_free:
            mode = cfg_spec_str(site_for(cfg.numerics, role, None, n_layers))
            out[mode] = out.get(mode, 0.0) + macs
        else:
            per_layer = macs / n_layers
            for layer in range(n_layers):
                mode = cfg_spec_str(site_for(cfg.numerics, role, layer, n_layers))
                out[mode] = out.get(mode, 0.0) + per_layer
    return out


# ---------------------------------------------------------------------------
# profiling hooks
# ---------------------------------------------------------------------------


def phase_annotation(name: str, enabled: bool = True):
    """Context manager annotating an engine phase in the jax profiler
    timeline.  A no-op (null context) when disabled or when
    jax.profiler is unavailable, so the hot path never pays for it."""
    if not enabled:
        import contextlib

        return contextlib.nullcontext()
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:  # pragma: no cover - jax always ships profiler
        import contextlib

        return contextlib.nullcontext()
    return TraceAnnotation(name)


# ---------------------------------------------------------------------------
# CLI: schema-check exported artifacts (CI runs this on bench artifacts)
# ---------------------------------------------------------------------------

_PROM_LINE = r"^(#.*|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [-+0-9.einfa]+)$"


def check_trace_file(path: str) -> Dict[str, int]:
    """Validate a trace.jsonl: every event schema-checks and every
    terminated request's sequence is grammatical.  Returns counts."""
    events = load_jsonl(path)
    by_rid: Dict[int, List[TraceEvent]] = {}
    for ev in events:
        by_rid.setdefault(ev.rid, []).append(ev)
    checked = 0
    for evs in by_rid.values():
        if evs[-1].etype in TERMINAL_EVENTS:
            check_request_events(evs)
            checked += 1
    return {"events": len(events), "requests": len(by_rid), "terminal": checked}


#: Metric families a serving-engine export must always carry: the
#: engine registers the prefix-cache counters unconditionally (they
#: simply stay at 0 with the cache off), so their absence from a file
#: that has any ``serve_`` family means the export predates the cache
#: or dropped families on the way out.
_REQUIRED_SERVE_FAMILIES: Tuple[str, ...] = (
    "serve_prefix_cache_hits_total",
    "serve_prefix_cache_misses_total",
    "serve_prefix_cache_evictions_total",
)


def check_prom_file(path: str) -> int:
    """Syntax-check a Prometheus text file; returns sample line count.
    Files containing serving-engine metrics must also carry the
    prefix-cache families (see :data:`_REQUIRED_SERVE_FAMILIES`)."""
    import re

    pat = re.compile(_PROM_LINE)
    samples = 0
    families = set()
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            if not pat.match(line):
                raise TraceInvariantError(f"{path}:{line_no}: bad prom line {line!r}")
            if not line.startswith("#"):
                samples += 1
                families.add(line.split("{", 1)[0].split(" ", 1)[0])
    if any(f.startswith("serve_") for f in families):
        missing = [f for f in _REQUIRED_SERVE_FAMILIES if f not in families]
        if missing:
            raise TraceInvariantError(
                f"{path}: serving export missing metric families {missing}"
            )
    return samples


def main(argv: Optional[Iterable[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="schema-check serving trace/metrics artifacts"
    )
    ap.add_argument("trace", help="trace.jsonl from --trace-out / serve_bench")
    ap.add_argument("--prom", default=None, help="metrics.prom to syntax-check")
    args = ap.parse_args(argv)
    counts = check_trace_file(args.trace)
    print(
        f"{args.trace}: {counts['events']} events, {counts['requests']} requests, "
        f"{counts['terminal']} terminal sequences OK"
    )
    if args.prom:
        n = check_prom_file(args.prom)
        print(f"{args.prom}: {n} samples OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
