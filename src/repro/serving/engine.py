"""Batched serving engine: prefill + greedy/sampled decode over the
uniform ModelAPI, with posit/PLAM numerics live in every matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import ModelAPI, build


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class Engine:
    """Minimal batched inference engine.

    `generate` runs one jitted prefill followed by a jitted
    lax.while-free python decode loop (each step is one jitted call —
    the deployment pattern when steps stream back to clients).
    """

    def __init__(self, cfg: ModelConfig, params=None, key=None):
        self.cfg = cfg
        self.api: ModelAPI = build(cfg)
        self.params = params if params is not None else self.api.init(
            key if key is not None else jax.random.PRNGKey(0))
        self._prefill = jax.jit(self.api.prefill)
        self._decode = jax.jit(self.api.decode_step)

    def generate(self, prompt_batch: dict, scfg: ServeConfig = ServeConfig()):
        """prompt_batch: family-appropriate prefill inputs (see
        ModelAPI.prefill_inputs).  Returns [B, max_new_tokens] tokens."""
        logits, caches = self._prefill(self.params, prompt_batch)
        b = logits.shape[0]
        if "tokens" in prompt_batch:
            pos0 = prompt_batch["tokens"].shape[1]
        else:
            pos0 = 0
        key = jax.random.PRNGKey(scfg.seed)
        out = []
        tok = self._pick(logits[:, -1, :], scfg, key)
        out.append(tok)
        for i in range(scfg.max_new_tokens - 1):
            batch = {"token": tok[:, None], "cache_len": jnp.int32(pos0 + i)}
            batch.update(self._cache_kw(caches, prompt_batch))
            logits, caches = self._decode(self.params, batch)
            key = jax.random.fold_in(key, i)
            tok = self._pick(logits[:, -1, :], scfg, key)
            out.append(tok)
        return jnp.stack(out, axis=1)

    def _cache_kw(self, caches, prompt_batch):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return {"kv_caches": caches}
        if fam in ("ssm", "hybrid"):
            return {"caches": caches}
        if fam == "encdec":
            # encoder output is fixed for the whole generation
            if not hasattr(self, "_enc_out"):
                from repro.models import encdec  # lazy to avoid cycle
            return {"kv_caches": caches, "enc_out": self._enc_cache}
        raise ValueError(fam)

    def _pick(self, logits, scfg: ServeConfig, key):
        if scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / scfg.temperature).astype(jnp.int32)
