"""Serving engines over the uniform ModelAPI, posit/PLAM numerics live
in every matmul.

Two engines:

* :class:`Engine` — the original static batcher: one fixed batch in,
  prefill once, decode in lockstep, everything padded to the longest
  prompt and running until the last sequence finishes.  Kept as the
  reference implementation (and for the stateful SSM/hybrid/encdec
  families, whose caches are not paged).
* :class:`ContinuousBatchingEngine` — admission-controlled request
  lifecycle over a paged KV cache: requests are admitted and retired
  every decode step, each sequence owns exactly the cache blocks it
  needs, and the jitted decode step gathers per-sequence block tables
  (`repro.models.transformer.paged_decode_step`).

The continuous engine is mesh-aware: ``PagedServeConfig.tp`` shards
model weights tensor-parallel (Megatron-style, via
``repro.parallel.sharding.param_shardings``) and the paged KV pool over
its kv-head axis (``seq_tp`` positions fallback for GQA), while the
block table, allocator and scheduler stay replicated host-side — the
control plane never notices the mesh.  ``prefill_chunk`` turns on
chunked prefill on top of either: long prompts are written in
fixed-size chunks, one per engine step, interleaved with decode, so a
long prompt bounds per-step latency instead of stalling every running
sequence behind one monolithic prefill.  ``spec_k`` turns on
speculative decoding: a drafter (``repro.serving.spec``) proposes k
tokens per slot, the target scores all k+1 positions in one batched
verify call, accepted prefixes commit and rejected tails roll back —
greedy-token-identical to plain decode, but up to k+1 tokens per step.

Both engines keep per-step wall-clock latencies in ``ServeStats`` so
benchmarks read p50/p95 from either engine through the same interface.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from functools import partial
from typing import Deque, Dict, Iterator, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ModelAPI, build
from repro.parallel.sharding import paged_pool_spec, param_shardings, use_mesh

from .kv_cache import BlockAllocator, SCRATCH_BLOCK, padded_prompt_len
from .observability import (
    MetricsRegistry,
    TraceRecorder,
    macs_per_token_by_mode,
    phase_annotation,
)
from .scheduler import Request, RequestState, Scheduler


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0
    # sync the device after every decode step so ServeStats records true
    # per-step wall latency.  Off by default: the sync costs a host
    # round-trip per token, and generate()'s plain callers should keep
    # XLA's async dispatch (benchmarks turn it on)
    time_steps: bool = False


@dataclasses.dataclass
class ServeStats:
    """Padding/utilization/latency accounting: what serve_bench reports.

    Both engines fill the same fields — ``step_latency_s`` holds one
    wall-clock entry per engine step (the static engine counts its
    prefill as step 0, then one entry per lockstep decode), so latency
    percentiles compare across engines without attribute guards.

    Since the observability layer, this class is a thin *façade*: the
    engines wire a :class:`~repro.serving.observability.MetricsRegistry`
    with live sources over these fields, and once bound (``_registry``)
    the latency quantiles are computed THROUGH the registry's
    ``serve_step_latency_seconds`` histogram — same numbers, one code
    path, and ``serve_bench`` reads the registry instead of reaching
    into fields.  Unbound instances (constructed standalone) keep the
    original list-based behavior.
    """

    steps: int = 0
    prefills: int = 0
    prefill_tokens: int = 0  # real prompt tokens
    prefill_padding: int = 0  # bucket/chunk padding on top of them
    decode_steps: int = 0
    active_slot_steps: int = 0  # slot-steps doing useful decode work
    idle_slot_steps: int = 0  # slot-steps wasted (empty slot, step ran)
    generated_tokens: int = 0
    # speculative decoding: per-verify-step draft/accept accounting
    spec_steps: int = 0  # batched verify steps run
    drafted_tokens: int = 0  # k drafts per active slot per verify step
    accepted_tokens: int = 0  # drafts the target model agreed with
    spec_committed_tokens: int = 0  # tokens committed via verify steps
    step_latency_s: List[float] = dataclasses.field(default_factory=list)
    # preemption / deadline accounting (preemption="recompute")
    preemptions: int = 0  # running sequences evicted under pool pressure
    resumes: int = 0  # preempted sequences re-admitted (recompute-resume)
    deadline_cancelled: int = 0  # requests cancelled at deadline expiry
    resume_latency_s: List[float] = dataclasses.field(default_factory=list)
    resume_latency_steps: List[int] = dataclasses.field(default_factory=list)
    # observability binding (engine-managed): once set, quantiles are
    # computed from the registry's step-latency histogram, whose live
    # source is this object's own step_latency_s — one source of truth
    _registry: Optional[MetricsRegistry] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def padding_waste(self) -> float:
        """Fraction of engine capacity spent on padding/idle slots."""
        spent = (
            self.prefill_tokens
            + self.prefill_padding
            + self.active_slot_steps
            + self.idle_slot_steps
        )
        wasted = self.prefill_padding + self.idle_slot_steps
        return wasted / spent if spent else 0.0

    def record_step(self, seconds: float) -> None:
        self.step_latency_s.append(seconds)

    def latency_quantile(self, q: float) -> float:
        if self._registry is not None:
            return self._registry.histogram("serve_step_latency_seconds").quantile(q)
        if not self.step_latency_s:
            return 0.0
        return float(np.quantile(np.asarray(self.step_latency_s), q))

    def latency_p50(self) -> float:
        return self.latency_quantile(0.50)

    def latency_p95(self) -> float:
        return self.latency_quantile(0.95)

    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target model accepted."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens

    def resume_latency_mean_s(self) -> float:
        """Mean wall seconds a preempted request spent parked before
        its recompute-resume was admitted."""
        if not self.resume_latency_s:
            return 0.0
        return float(np.mean(np.asarray(self.resume_latency_s)))

    def tokens_per_verify_step(self) -> float:
        """Mean committed tokens per verify step per active slot — the
        speculative speedup over one-token-per-step decode (1.0 = no
        speedup, k+1 = every draft accepted)."""
        return (
            self.spec_committed_tokens / self.active_slot_steps
            if self.spec_steps and self.active_slot_steps
            else 0.0
        )


class Engine:
    """Minimal batched inference engine (static batching).

    `generate` runs one jitted prefill followed by a jitted
    lax.while-free python decode loop (each step is one jitted call —
    the deployment pattern when steps stream back to clients).
    """

    def __init__(self, cfg: ModelConfig, params=None, key=None, prequantize=False):
        self.cfg = cfg
        self.api: ModelAPI = build(cfg)
        self.params = (
            params
            if params is not None
            else self.api.init(key if key is not None else jax.random.PRNGKey(0))
        )
        self.prequant_meta = {}
        if prequantize:
            from repro.core.prequant import quantize_params

            self.params, self.prequant_meta = quantize_params(cfg, self.params)
        self._prefill = jax.jit(self.api.prefill)
        self._decode = jax.jit(self.api.decode_step)
        self._enc_cache = None  # encdec: encoder output, fixed per generate()
        self.stats = ServeStats()
        # same registry surface as the continuous engine (sourced subset:
        # the static batcher has no pool / scheduler / drafter to sample)
        self.metrics = MetricsRegistry()
        for mname, field in (
            ("serve_steps_total", "steps"),
            ("serve_prefills_total", "prefills"),
            ("serve_prefill_tokens_total", "prefill_tokens"),
            ("serve_decode_steps_total", "decode_steps"),
            ("serve_generated_tokens_total", "generated_tokens"),
        ):
            self.metrics.counter(mname).set_source(
                lambda field=field: getattr(self.stats, field)
            )
        self.metrics.histogram("serve_step_latency_seconds").set_source(
            lambda: self.stats.step_latency_s
        )
        self.stats._registry = self.metrics

    def generate(self, prompt_batch: dict, scfg: ServeConfig = ServeConfig()):
        """prompt_batch: family-appropriate prefill inputs (see
        ModelAPI.prefill_inputs).  Returns [B, max_new_tokens] tokens.

        ``self.stats`` is reset per call and filled with the same
        counters the continuous engine keeps: step 0 is the whole
        prefill (+ first sampled token), every later step one lockstep
        decode over the full batch.  Per-step wall latencies are only
        recorded under ``scfg.time_steps`` (they require a device sync
        per step, which would break async dispatch for normal callers).
        """
        self.stats = ServeStats()
        self.stats._registry = self.metrics
        self._enc_cache = None  # recomputed per generate (frames differ)
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, prompt_batch)
        b = logits.shape[0]
        if "tokens" in prompt_batch:
            pos0 = prompt_batch["tokens"].shape[1]
            if "embeds_prefix" in prompt_batch:
                # vlm: patch embeddings occupy the cache prefix, so the
                # first decode write/position comes after patches+tokens
                pos0 += prompt_batch["embeds_prefix"].shape[1]
        else:
            pos0 = 0
        caches = self._grow_caches(caches, scfg.max_new_tokens)
        key = jax.random.PRNGKey(scfg.seed)
        out = []
        tok = self._pick(logits[:, -1, :], scfg, key)
        if scfg.time_steps:
            jax.block_until_ready(tok)
            self.stats.record_step(time.perf_counter() - t0)
        out.append(tok)
        self.stats.steps += 1
        self.stats.prefills += 1
        self.stats.prefill_tokens += b * pos0
        self.stats.generated_tokens += b
        for i in range(scfg.max_new_tokens - 1):
            t0 = time.perf_counter()
            batch = {"token": tok[:, None], "cache_len": jnp.int32(pos0 + i)}
            batch.update(self._cache_kw(caches, prompt_batch))
            logits, caches = self._decode(self.params, batch)
            key = jax.random.fold_in(key, i)
            tok = self._pick(logits[:, -1, :], scfg, key)
            if scfg.time_steps:
                jax.block_until_ready(tok)
                self.stats.record_step(time.perf_counter() - t0)
            out.append(tok)
            self.stats.steps += 1
            self.stats.decode_steps += 1
            self.stats.active_slot_steps += b
            self.stats.generated_tokens += b
        return jnp.stack(out, axis=1)

    def _grow_caches(self, caches, max_new_tokens: int):
        """Prefill allocates caches sized to the prompt; decode then
        writes at positions prompt_len..prompt_len+max_new-2, which a
        prompt-sized cache would clamp onto its last slot (silently
        overwriting the final prompt entry).  Pad the seq axis up front
        so every decode write lands in a real slot."""
        if (
            self.cfg.family not in ("dense", "moe", "vlm", "encdec")
            or max_new_tokens <= 1
        ):
            return caches
        pad = ((0, 0), (0, 0), (0, max_new_tokens - 1), (0, 0), (0, 0))
        ck, cv = caches
        return jnp.pad(ck, pad), jnp.pad(cv, pad)

    def _cache_kw(self, caches, prompt_batch):
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm"):
            return {"kv_caches": caches}
        if fam in ("ssm", "hybrid"):
            return {"caches": caches}
        if fam == "encdec":
            # the encoder output is fixed for the whole generation but
            # api.prefill does not return it — recompute it once from
            # the prompt frames and reuse it for every decode step
            if self._enc_cache is None:
                from repro.models import encdec  # lazy to avoid cycle

                self._enc_cache = jax.jit(partial(encdec.encode, self.cfg))(
                    self.params, prompt_batch["frames"]
                )
            return {"kv_caches": caches, "enc_out": self._enc_cache}
        raise ValueError(fam)

    def _pick(self, logits, scfg: ServeConfig, key):
        if scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / scfg.temperature).astype(jnp.int32)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PagedServeConfig:
    """Static capacity of a continuous-batching engine instance.

    block_size: cache positions per KV block.
    num_blocks: pool size (block 0 is reserved scratch, so
        num_blocks - 1 are allocatable).
    max_slots: max sequences decoded per step (the jitted batch width).
    max_seq_len: per-sequence prompt + generated cap; fixes the block
        table width to ceil(max_seq_len / block_size).
    tp: tensor-parallel ways.  >1 builds a (data=1, model=tp) mesh over
        the first tp local devices, shards parameters Megatron-style and
        the KV pool per ``repro.parallel.sharding.paged_pool_spec``; on
        CPU force devices first with
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    prefill_chunk: 0 = whole-prompt prefill (one bucket-padded call per
        request).  >0 = chunked prefill: prompts are written
        ``prefill_chunk`` tokens per engine step, interleaved with
        decode.  Must be a multiple of block_size so chunk starts stay
        block-aligned inside the sequence's allocation.
    """

    block_size: int = 16
    num_blocks: int = 128
    max_slots: int = 4
    max_seq_len: int = 256
    temperature: float = 0.0
    seed: int = 0
    cache_dtype: str = "bfloat16"
    use_kernel: Optional[bool] = None  # None = auto (Pallas on TPU)
    tp: int = 1
    prefill_chunk: int = 0
    # encode policy-selected weights to posit patterns once at engine
    # construction (core.prequant.quantize_params); plam_sim sites then
    # serve through kernels.ops.plam_dense with int16 weight storage
    prequantize: bool = False
    # speculative decoding: 0 = off; k > 0 drafts k tokens per active
    # slot per step and verifies all k+1 positions in one batched call
    # (requires greedy sampling — acceptance is exact argmax agreement,
    # so the committed stream is token-identical to spec_k=0).
    # Admission reserves blocks for the worst-case k-token burst and
    # rejected tails are rolled back (stale K/V scrubbed at retirement).
    spec_k: int = 0
    # drafter: "ngram" / "ngram:N" (self-speculative context lookup),
    # "model:<arch>" (registry draft model sharing the tokenizer), or a
    # Drafter instance (repro.serving.spec)
    spec_draft: object = "ngram"
    # preemptive scheduling under KV pressure.  "off" = PR 1-4
    # behavior: admission reserves whole-lifetime blocks, FCFS, no
    # eviction.  "recompute" = admission allocates only the prefill
    # context, sequences grow on demand, and under pool pressure the
    # least deserving running request (lowest Request.priority, then
    # latest arrival) is preempted — all its written blocks scrubbed —
    # and later resumed by recomputing its committed tokens through the
    # chunked-prefill path; resumed streams are greedy-token-identical
    # to uninterrupted runs.
    preemption: str = "off"
    # content-addressed prefix caching over the paged pool (opt-in).
    # Admission walks the prompt's full blocks through a chain-hash map
    # (hash(parent_hash, block_tokens)) kept by the BlockAllocator,
    # reuses every leading hit (refcount++) and prefills only the miss
    # suffix through the chunked-prefill path; freed registered blocks
    # park on an LRU and are evicted (scrubbed, then freed) only under
    # pool pressure.  Greedy streams are token-identical with the cache
    # on or off — K/V at a position is a deterministic function of the
    # token prefix, which is exactly what the chain hash keys.
    prefix_cache: bool = False
    # injectable wall clock (monotonic seconds) for deadline expiry and
    # resume-latency stats; None = time.monotonic.  Tests inject a fake
    # clock to drive Request.deadline_s deterministically.
    clock: Optional[object] = None
    # observability (see repro.serving.observability).  trace=True (the
    # default) records one typed TraceEvent per request lifecycle
    # transition — host-side appends, well under 5% of a step's cost —
    # exportable as JSON-lines / Chrome trace and the source of the
    # per-request latency breakdown.  profile=True additionally wraps
    # each engine phase in a jax.profiler TraceAnnotation so phases
    # show as named spans in a profiler capture (off by default: it is
    # only meaningful inside jax.profiler.trace()).
    trace: bool = True
    profile: bool = False


class ContinuousBatchingEngine:
    """Admission-controlled serving over a paged KV cache.

    Each `step()`:
      1. admits waiting requests FCFS while a slot + blocks are free —
         whole-prompt prefill immediately, or queued for chunked
         prefill when ``prefill_chunk`` is set;
      2. feeds at most ONE prompt chunk (head-of-line) when chunking;
      3. runs ONE jitted batched decode step over all fully-prefilled
         slots, gathering per-sequence block tables and lengths — or,
         under ``spec_k``, ONE batched k+1-position verify step that
         commits each slot's accepted draft prefix plus the target's
         correction token and rolls back the rejected tail;
      4. retires finished sequences, returning blocks to the free list
         (stale never-committed K/V scrubbed first).

    Supported families: dense / moe (attention KV caches).  SSM, hybrid
    and enc-dec keep the static :class:`Engine` — their caches are
    O(1)-state or encoder-tied, so paging buys nothing.

    Under ``tp > 1`` every jitted call runs inside the engine's mesh:
    parameters and KV pool are device_put with their shardings once at
    construction, activations follow the model's ``constrain`` rules,
    and decode attention dispatches to the head-sharded shard_map path
    (`repro.kernels.decode_attention.paged_decode_attention_tp`) when
    kv heads divide tp.  Host-side state (block tables, lengths, last
    tokens, the scheduler) is identical to the single-device engine.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params=None,
        key=None,
        pcfg: PagedServeConfig = PagedServeConfig(),
    ):
        self.cfg = cfg
        self.pcfg = pcfg
        self.api: ModelAPI = build(cfg)
        if self.api.paged_decode_step is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged KV layout; use Engine"
            )
        if cfg.attn_logit_softcap is not None:
            raise ValueError("paged decode does not support logit softcap")
        if pcfg.prefill_chunk and pcfg.prefill_chunk % pcfg.block_size:
            raise ValueError(
                f"prefill_chunk={pcfg.prefill_chunk} must be a multiple of "
                f"block_size={pcfg.block_size}"
            )
        if pcfg.prefill_chunk and self.api.paged_prefill_chunk is None:
            raise ValueError(f"family {cfg.family!r} has no chunked prefill path")
        if pcfg.prefix_cache and self.api.paged_prefill_chunk is None:
            raise ValueError(
                f"family {cfg.family!r} has no chunked prefill path; "
                "prefix caching needs it to prefill the cache-miss suffix"
            )
        if pcfg.spec_k:
            if pcfg.temperature > 0:
                raise ValueError(
                    "speculative decoding requires greedy sampling "
                    "(temperature=0): acceptance is exact argmax agreement"
                )
            if self.api.paged_score_tokens is None:
                raise ValueError(
                    f"family {cfg.family!r} has no multi-token scoring path"
                )

        self._mesh = None
        if pcfg.tp > 1:
            ndev = len(jax.devices())
            if ndev < pcfg.tp:
                raise ValueError(
                    f"tp={pcfg.tp} needs at least {pcfg.tp} devices, found {ndev}; "
                    "on CPU force more with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N"
                )
            self._mesh = jax.make_mesh((1, pcfg.tp), ("data", "model"))

        self.params = (
            params
            if params is not None
            else self.api.init(key if key is not None else jax.random.PRNGKey(0))
        )
        self.prequant_meta = {}
        if pcfg.prequantize:
            from repro.core.prequant import quantize_params

            self.params, self.prequant_meta = quantize_params(cfg, self.params)

        bs, nb = pcfg.block_size, pcfg.num_blocks
        # the block table is wide enough for the worst-case speculative
        # burst: a verify step may write spec_k positions past the
        # committed tail before acceptance is known, and those writes
        # must land in the sequence's own (reserved) blocks — never be
        # clamped back onto committed positions by dynamic_update_slice
        self.max_blocks_per_seq = -(-(pcfg.max_seq_len + pcfg.spec_k) // bs)
        dtype = jnp.dtype(pcfg.cache_dtype)
        self._k_pool, self._v_pool = self.api.paged_pool_init(nb, bs, dtype)
        if self._mesh is not None:
            self.params = jax.device_put(
                self.params, param_shardings(self._mesh, self.params)
            )
            pool_sharding = paged_pool_spec(self._mesh, self._k_pool.shape)
            self._k_pool = jax.device_put(self._k_pool, pool_sharding)
            self._v_pool = jax.device_put(self._v_pool, pool_sharding)
        self.allocator = BlockAllocator(nb, bs, prefix_cache=pcfg.prefix_cache)
        self._clock = pcfg.clock if pcfg.clock is not None else time.monotonic
        self.scheduler = Scheduler(
            self.allocator,
            pcfg.max_slots,
            pcfg.max_seq_len,
            spec_k=pcfg.spec_k,
            preemption=pcfg.preemption,
            clock=self._clock,
        )

        donate = (2, 3) if jax.default_backend() != "cpu" else ()
        self._prefill = jax.jit(self.api.paged_prefill, donate_argnums=donate)
        self._prefill_chunk = (
            jax.jit(self.api.paged_prefill_chunk, donate_argnums=donate)
            if self.api.paged_prefill_chunk is not None
            else None
        )
        self._decode = jax.jit(
            partial(self.api.paged_decode_step, use_kernel=pcfg.use_kernel),
            donate_argnums=donate,
        )
        self.drafter = None
        self._score = None
        if pcfg.spec_k:
            from .spec import make_drafter

            self.drafter = (
                make_drafter(
                    pcfg.spec_draft, cfg, key=jax.random.PRNGKey(pcfg.seed)
                )
                if isinstance(pcfg.spec_draft, str)
                else pcfg.spec_draft
            )
            self._score = jax.jit(self.api.paged_score_tokens, donate_argnums=donate)
        # zero freed blocks that still hold written-but-never-committed
        # K/V (rolled-back draft tails, prefill padding) before the
        # allocator can hand them to another sequence; the id row is
        # padded with the scratch block so every scrub shares one
        # compile (re-zeroing scratch is harmless)
        scrub_donate = (0, 1) if jax.default_backend() != "cpu" else ()
        self._scrub_fn = jax.jit(
            lambda kp, vp, ids: (kp.at[:, ids].set(0), vp.at[:, ids].set(0)),
            donate_argnums=scrub_donate,
        )
        # blocks freed this step but not yet zeroed: scrubs coalesce
        # into one padded scatter per flush (see _flush_scrubs) instead
        # of one dispatch per retire/preempt/evict event
        self._scrub_pending: List[int] = []
        # copy-on-write: duplicate one pool block (all layers) into a
        # private block before a sequence writes into a shared tail
        self._cow_fn = jax.jit(
            lambda kp, vp, src, dst: (
                kp.at[:, dst].set(kp[:, src]),
                vp.at[:, dst].set(vp[:, src]),
            ),
            donate_argnums=scrub_donate,
        )

        m = pcfg.max_slots
        self._tables = np.full((m, self.max_blocks_per_seq), SCRATCH_BLOCK, np.int32)
        self._lengths = np.zeros((m,), np.int32)
        self._last_tok = np.zeros((m,), np.int32)
        self._prefilling: Deque[Request] = deque()
        self._step_no = 0
        self._next_rid = 0
        self.stats = ServeStats()
        # observability: trace recorder (on by default — cheap host-side
        # appends), metrics registry wired with live sources, opt-in
        # profiler annotations
        self._profile = pcfg.profile
        self.trace: Optional[TraceRecorder] = (
            TraceRecorder(
                clock=self._clock,
                occupancy=lambda: (self.allocator.num_free, self.allocator.num_used),
            )
            if pcfg.trace
            else None
        )
        self.metrics = MetricsRegistry()
        self._wire_metrics()
        self.stats._registry = self.metrics

    @property
    def current_step(self) -> int:
        """Engine step counter (arrival_step values are absolute)."""
        return self._step_no

    def _wire_metrics(self) -> None:
        """Register every serving metric with a live *source* over
        engine state — collection reads current values on demand, so
        the hot path pays nothing and a benchmark-style
        ``eng.stats = ServeStats()`` reset is reflected automatically.
        Per-numerics-mode MAC counters resolve each matmul site through
        ``repro.core.policy`` (PLAM's savings as a serving metric)."""
        m = self.metrics
        counters = {
            "serve_steps_total": ("engine steps run", lambda: self.stats.steps),
            "serve_prefills_total": (
                "prefill calls (whole-prompt or chunk)",
                lambda: self.stats.prefills,
            ),
            "serve_prefill_tokens_total": (
                "real prompt tokens written",
                lambda: self.stats.prefill_tokens,
            ),
            "serve_prefill_padding_total": (
                "bucket/chunk padding tokens",
                lambda: self.stats.prefill_padding,
            ),
            "serve_decode_steps_total": (
                "batched decode/verify steps",
                lambda: self.stats.decode_steps,
            ),
            "serve_generated_tokens_total": (
                "committed output tokens",
                lambda: self.stats.generated_tokens,
            ),
            "serve_drafted_tokens_total": (
                "speculative tokens drafted",
                lambda: self.stats.drafted_tokens,
            ),
            "serve_accepted_tokens_total": (
                "speculative tokens accepted",
                lambda: self.stats.accepted_tokens,
            ),
            "serve_preemptions_total": (
                "running sequences evicted",
                lambda: self.stats.preemptions,
            ),
            "serve_resumes_total": (
                "recompute-resume re-admissions",
                lambda: self.stats.resumes,
            ),
            "serve_deadline_cancelled_total": (
                "requests cancelled at deadline",
                lambda: self.stats.deadline_cancelled,
            ),
            "serve_prefix_cache_hits_total": (
                "prefix-cache block hits at admission",
                lambda: self.allocator.hits,
            ),
            "serve_prefix_cache_misses_total": (
                "prefix-cache block misses at admission",
                lambda: self.allocator.misses,
            ),
            "serve_prefix_cache_evictions_total": (
                "idle cached blocks reclaimed under pool pressure",
                lambda: self.allocator.evictions,
            ),
            "serve_prefill_tokens_saved_total": (
                "prompt tokens skipped via prefix-cache hits",
                lambda: self.allocator.tokens_saved,
            ),
            "serve_prefix_cache_cow_total": (
                "copy-on-write block duplications",
                lambda: self.allocator.cow_copies,
            ),
        }
        for name, (help_, src) in counters.items():
            m.counter(name, help_).set_source(src)
        gauges = {
            "serve_pool_blocks_free": (
                "KV pool blocks on the free list",
                lambda: self.allocator.num_free,
            ),
            "serve_pool_blocks_used": (
                "KV pool blocks owned by live sequences",
                lambda: self.allocator.num_used,
            ),
            "serve_pool_utilization": (
                "fraction of allocatable KV pool in use",
                self.allocator.utilization,
            ),
            "serve_prefix_cached_blocks": (
                "pool blocks holding registered prefix-cache content",
                lambda: self.allocator.num_cached,
            ),
            "serve_waiting_requests": (
                "submitted, not yet admitted",
                lambda: self.scheduler.num_waiting,
            ),
            "serve_preempted_requests": (
                "parked awaiting recompute-resume",
                lambda: self.scheduler.num_preempted,
            ),
            "serve_running_requests": (
                "admitted sequences holding a slot",
                lambda: self.scheduler.num_running,
            ),
            "serve_padding_waste": (
                "capacity fraction lost to padding/idle slots",
                lambda: self.stats.padding_waste(),
            ),
            "serve_spec_acceptance_rate": (
                "fraction of drafts the target accepted",
                lambda: self.stats.acceptance_rate(),
            ),
            "serve_tokens_per_verify_step": (
                "committed tokens per verify step per slot",
                lambda: self.stats.tokens_per_verify_step(),
            ),
            "serve_tok_per_s": (
                "generated tokens over summed step wall time",
                lambda: (
                    self.stats.generated_tokens / t
                    if (t := sum(self.stats.step_latency_s))
                    else 0.0
                ),
            ),
        }
        for name, (help_, src) in gauges.items():
            m.gauge(name, help_).set_source(src)
        m.histogram(
            "serve_step_latency_seconds", "wall seconds per engine step"
        ).set_source(lambda: self.stats.step_latency_s)
        try:
            by_mode = macs_per_token_by_mode(self.cfg)
        except Exception:  # exotic family/policy: MAC attribution is best-effort
            by_mode = {}
        for mode, macs in sorted(by_mode.items()):
            m.counter(
                "serve_macs_total",
                "forward-pass MACs by resolved numerics mode",
                mode=mode,
            ).set_source(
                lambda macs=macs: macs
                * (self.stats.prefill_tokens + self.stats.generated_tokens)
            )
        if self.drafter is not None:
            m.counter(
                "serve_draft_proposals_total", "drafter propose() calls"
            ).set_source(lambda: getattr(self.drafter, "proposals", 0))
            m.counter(
                "serve_draft_proposed_tokens_total", "tokens proposed by drafter"
            ).set_source(lambda: getattr(self.drafter, "proposed_tokens", 0))

    def _emit(self, etype: str, rid: int, **payload) -> None:
        """Trace hook: record one typed event (no-op when tracing off)."""
        if self.trace is not None:
            self.trace.emit(etype, rid, self._step_no, **payload)

    def _mesh_ctx(self):
        """Context manager activating the engine's mesh (no-op at tp=1)."""
        if self._mesh is None:
            return contextlib.nullcontext()
        return use_mesh(self._mesh)

    # -- request intake ----------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: int = 16,
        arrival_step: int = 0,
        stop_token: Optional[int] = None,
        priority: int = 0,
        deadline_s: Optional[float] = None,
    ) -> "SubmitHandle":
        """Queue a request; returns a :class:`~repro.serving.api.
        SubmitHandle` exposing ``.result()`` / ``.cancel()`` /
        ``.trace()`` and delegating every ``Request`` attribute, so
        pre-redesign callers keep working unchanged.  Requests must
        be submitted in non-decreasing arrival_step order.  ``priority``
        orders admission and preemption immunity under
        ``preemption="recompute"`` (larger wins; FCFS ignores it);
        ``deadline_s`` is a wall-clock budget from now — an expired
        request is cancelled wherever it is, keeping any output already
        committed."""
        from .api import SubmitHandle  # local: api imports this module

        req = Request(
            rid=self._next_rid,
            prompt=[int(t) for t in prompt],
            max_new_tokens=max_new_tokens,
            arrival_step=arrival_step,
            stop_token=stop_token,
            priority=priority,
            deadline_s=deadline_s,
            submit_time=self._clock(),
        )
        self._next_rid += 1
        self.scheduler.submit(req)
        self._emit(
            "SUBMIT",
            req.rid,
            prompt_len=req.prompt_len,
            max_new=req.max_new_tokens,
            priority=req.priority,
            arrival_step=req.arrival_step,
        )
        return SubmitHandle(self, req)

    def cancel(self, req) -> None:
        """Client-side abort: cancel ``req`` (a ``Request`` or a
        ``SubmitHandle``) wherever it is (waiting, running, preempted),
        keeping its committed output.  No-op for already-finished/
        cancelled requests."""
        req = getattr(req, "request", req)
        if req.state in (RequestState.FINISHED, RequestState.CANCELLED):
            return
        self._cancel(req, self._step_no)
        self._emit("CANCEL", req.rid, reason="client", out_len=len(req.output))

    # -- engine loop -------------------------------------------------------

    def step(self) -> List[Request]:
        """One engine iteration; returns requests finished this step.

        With chunked prefill, at most one prompt chunk (head-of-line
        FCFS) is processed before the decode step for every
        fully-prefilled sequence — per-step latency stays bounded by
        one chunk + one decode instead of one whole prompt.
        """
        t0 = time.perf_counter()
        step = self._step_no
        finished: List[Request] = []

        # deadline sweep: expired requests are cancelled wherever they
        # live (waiting / running / preempted), keeping committed output
        for req in self.scheduler.expired(self._clock()):
            self._cancel(req, step)
            self.stats.deadline_cancelled += 1
            self._emit(
                "DEADLINE",
                req.rid,
                deadline_s=req.deadline_s,
                out_len=len(req.output),
            )
            finished.append(req)

        for req in self.scheduler.admit(step, on_preempt=self._on_preempt):
            if req.preempted_step >= 0:  # recompute-resume re-admission
                self.stats.resumes += 1
                self.stats.resume_latency_steps.append(step - req.preempted_step)
                self.stats.resume_latency_s.append(
                    self._clock() - req.preempted_time
                )
                self._emit(
                    "RESUME",
                    req.rid,
                    slot=req.slot,
                    blocks=len(req.alloc.blocks),
                    parked_steps=step - req.preempted_step,
                    cached_len=req.cached_len,
                )
                req.preempted_step = -1
            else:
                self._emit(
                    "ADMIT",
                    req.rid,
                    slot=req.slot,
                    blocks=len(req.alloc.blocks),
                    cached_len=req.cached_len,
                )
            if self.pcfg.prefill_chunk:
                # blocks + slot reserved; the prompt is fed chunkwise
                # (the slot stays scratch-masked until prefill is done)
                self._prefilling.append(req)
            else:
                self._do_prefill(req)
                if req.is_done():  # max_new_tokens == 1: done at prefill
                    self._release(req, step)
                    finished.append(req)

        if self._prefilling:
            req = self._prefilling[0]
            if self._do_prefill_chunk(req):
                self._prefilling.popleft()
                if req.is_done():  # max_new_tokens == 1 / stop at first token
                    self._release(req, step)
                    finished.append(req)

        if self.pcfg.preemption == "recompute":
            self._grow_active(step)

        if any(r.prefill_done for r in self.scheduler.running.values()):
            if self.pcfg.spec_k:
                finished.extend(self._do_verify(step))
            else:
                finished.extend(self._do_decode(step))

        # drain any scrub work this step produced after its last
        # compute call (retires, cancels, deadline sweeps on an
        # otherwise-idle step) so freed blocks never stay dirty across
        # a step boundary
        self._flush_scrubs()

        self.stats.steps += 1
        self._step_no += 1
        self.stats.record_step(time.perf_counter() - t0)
        # benchmarks reset counters with `eng.stats = ServeStats()`; the
        # registry's source callables read `self.stats.<field>` live, so
        # the swap is already reflected — only the façade's back-pointer
        # needs refreshing for latency_quantile() to keep routing here.
        if self.stats._registry is not self.metrics:
            self.stats._registry = self.metrics
        self.metrics.tick(self._step_no)
        return finished

    def run(self) -> Dict[int, List[int]]:
        """Drive step() until every submitted request has finished.
        Returns {rid: generated tokens}."""
        done: Dict[int, List[int]] = {}
        while self.scheduler.has_work():
            for req in self.step():
                done[req.rid] = req.output
        return done

    def stream(self, prompt: List[int], **submit_kw) -> Iterator[dict]:
        """Submit one prompt and drive the engine, yielding incremental
        progress as dicts: ``{"tokens": [...]}`` for tokens committed
        since the previous yield, interleaved (in emission order) with
        ``{"event": TraceEvent}`` for this request's trace events when
        tracing is on.  Other queued requests keep making progress —
        stream() drives the shared ``step()`` loop, it does not pin the
        engine to one request.  Terminates after the request's terminal
        event (FINISH / CANCEL / DEADLINE)."""
        handle = self.submit(prompt, **submit_kw)
        req = handle.request
        n_tok = 0
        n_evt = 0
        if self.trace is not None:
            for ev in self.trace.request_events(req.rid)[n_evt:]:
                n_evt += 1
                yield {"event": ev}
        while req.state not in (RequestState.FINISHED, RequestState.CANCELLED):
            self.step()
            if self.trace is not None:
                for ev in self.trace.request_events(req.rid)[n_evt:]:
                    n_evt += 1
                    yield {"event": ev}
            if len(req.output) > n_tok:
                new = req.output[n_tok:]
                n_tok = len(req.output)
                yield {"tokens": new}

    # -- internals ---------------------------------------------------------

    def _do_prefill(self, req: Request) -> None:
        """Whole-context prefill: the prompt for a fresh request, or —
        on a recompute-resume — the frozen committed context.  A resume
        routes through the chunked-prefill gather->attend->scatter path
        (one whole-width chunk) when the family has one: it is pinned
        bit-identical to monolithic prefill and shares its compiles
        with chunked serving.  A prefix-cache hit (``prefill_pos > 0``
        set by admission) takes the same route: only the miss suffix is
        written, over the shared blocks as attended context."""
        if (
            req.resume_ctx is not None or req.prefill_pos > 0
        ) and self._prefill_chunk is not None:
            self._resume_via_chunk(req)
            return
        self._flush_scrubs()
        bs = self.pcfg.block_size
        plen = req.prefill_len
        s_pad = padded_prompt_len(plen, bs)
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :plen] = req.prefill_tokens
        block_ids = jnp.asarray(req.alloc.blocks[: s_pad // bs], jnp.int32)
        with self._mesh_ctx(), phase_annotation("serve.prefill", self._profile):
            logits, (self._k_pool, self._v_pool) = self._prefill(
                self.params,
                jnp.asarray(toks),
                self._k_pool,
                self._v_pool,
                block_ids,
                jnp.int32(plen),
            )
        req.prefill_pos = plen
        req.verified_len = plen
        req.drafted_len = s_pad  # pad positions hold junk K/V until overwritten
        self._finish_prefill(req, logits[0, -1])
        self.stats.prefills += 1
        self.stats.prefill_tokens += plen
        self.stats.prefill_padding += s_pad - plen
        self._emit(
            "PREFILL_CHUNK",
            req.rid,
            start=0,
            tokens=plen,
            width=s_pad,
            done=True,
            out_len=len(req.output),
        )

    def _resume_via_chunk(self, req: Request) -> None:
        """Recompute-resume: rewrite the K/V of the committed context
        into freshly-allocated blocks with ONE padded
        ``paged_prefill_chunk`` call.  The logits are discarded — the
        next token after the context is the already-committed last
        output token, re-fed by the normal decode step — so resume only
        has to reproduce the K/V, which the chunk path does
        bit-identically to an uninterrupted run.

        With prefix caching the same path prefills only the cache-MISS
        suffix: ``prefill_pos`` starts at the cached boundary (set by
        admission), the chunk's ``cache_len`` is that boundary, and the
        hit blocks are attended over exactly as committed context is on
        a resume.  ``start == 0`` reproduces the historical resume call
        bit-for-bit."""
        if req.cow_src is not None:
            self._apply_cow(req)
        self._flush_scrubs()
        bs = self.pcfg.block_size
        plen = req.prefill_len
        start = req.prefill_pos
        remaining = plen - start
        width = padded_prompt_len(remaining, bs)
        toks = np.zeros((1, width), np.int32)
        toks[0, :remaining] = req.prefill_tokens[start:]
        table_row = jnp.asarray(
            req.alloc.table_row(self.max_blocks_per_seq), jnp.int32
        )
        with self._mesh_ctx(), phase_annotation("serve.prefill", self._profile):
            logits, (self._k_pool, self._v_pool) = self._prefill_chunk(
                self.params,
                jnp.asarray(toks),
                self._k_pool,
                self._v_pool,
                table_row,
                jnp.int32(start),
                jnp.int32(remaining - 1),
            )
        req.prefill_pos = plen
        req.verified_len = plen
        # suffix padding past capacity lands on the scratch block via
        # the padded table row; only in-capacity positions can be dirty
        req.drafted_len = max(
            req.drafted_len, min(start + width, req.alloc.capacity())
        )
        self._finish_prefill(req, logits[0, -1])
        self.stats.prefills += 1
        self.stats.prefill_tokens += remaining
        self.stats.prefill_padding += width - remaining
        self._emit(
            "PREFILL_CHUNK",
            req.rid,
            start=start,
            tokens=remaining,
            width=width,
            done=True,
            out_len=len(req.output),
        )

    def _finish_prefill(self, req: Request, last_logits) -> None:
        """Activate a fully-prefilled slot.  Fresh requests sample
        their first token from the prefill logits; a resumed request
        already committed that continuation — its last output token is
        re-fed as the next decode input instead (sampling again would
        double-emit it)."""
        if req.output:
            tok = req.output[-1]
        else:
            tok = int(self._pick_one(last_logits, req, len(req.output)))
            req.output.append(tok)
            self.stats.generated_tokens += 1
        slot = req.slot
        self._tables[slot] = req.alloc.table_row(self.max_blocks_per_seq)
        self._lengths[slot] = req.prefill_len
        self._last_tok[slot] = tok
        if self.allocator.prefix_cache:
            # publish only now that the K/V is really in the pool — a
            # hash->block mapping must never race ahead of pool content
            self.allocator.register(req.prefill_tokens, req.alloc.blocks)

    def _do_prefill_chunk(self, req: Request) -> bool:
        """Write ONE chunk of `req`'s prompt into its pool blocks.

        Returns True when the prompt is fully cached — the first token
        is then sampled and the slot activated for decode.  The chunk
        width is fixed at prefill_chunk (one compile); the ragged final
        chunk is padded to a block multiple (<= chunk width, one
        compile per distinct residue bucket — same trade as the
        whole-prompt buckets).
        """
        if req.cow_src is not None:
            self._apply_cow(req)
        self._flush_scrubs()
        bs, chunk = self.pcfg.block_size, self.pcfg.prefill_chunk
        start = req.prefill_pos
        remaining = req.prefill_len - start
        width = chunk if remaining > chunk else padded_prompt_len(remaining, bs)
        real = min(remaining, chunk)
        toks = np.zeros((1, width), np.int32)
        toks[0, :real] = req.prefill_tokens[start : start + real]
        table_row = jnp.asarray(
            req.alloc.table_row(self.max_blocks_per_seq), jnp.int32
        )
        with self._mesh_ctx(), phase_annotation("serve.prefill", self._profile):
            logits, (self._k_pool, self._v_pool) = self._prefill_chunk(
                self.params,
                jnp.asarray(toks),
                self._k_pool,
                self._v_pool,
                table_row,
                jnp.int32(start),
                jnp.int32(real - 1),
            )
        req.prefill_pos = start + real
        req.verified_len = start + real
        # chunk padding past capacity is absorbed by the scratch block
        # (padded table row) — only in-capacity positions can be dirty
        req.drafted_len = max(
            req.drafted_len, min(start + width, req.alloc.capacity())
        )
        self.stats.prefills += 1
        self.stats.prefill_tokens += real
        self.stats.prefill_padding += width - real
        if not req.prefill_done:
            self._emit(
                "PREFILL_CHUNK",
                req.rid,
                start=start,
                tokens=real,
                width=width,
                done=False,
                out_len=len(req.output),
            )
            return False
        self._finish_prefill(req, logits[0, -1])
        self._emit(
            "PREFILL_CHUNK",
            req.rid,
            start=start,
            tokens=real,
            width=width,
            done=True,
            out_len=len(req.output),
        )
        return True

    def _do_decode(self, step: int) -> List[Request]:
        self._flush_scrubs()
        token = jnp.asarray(self._last_tok[:, None])
        with self._mesh_ctx(), phase_annotation("serve.decode", self._profile):
            logits, (self._k_pool, self._v_pool) = self._decode(
                self.params,
                token,
                self._k_pool,
                self._v_pool,
                jnp.asarray(self._tables),
                jnp.asarray(self._lengths),
            )
        logits = np.asarray(logits[:, 0], np.float32)

        finished = []
        active = [
            (slot, req)
            for slot, req in self.scheduler.running.items()
            if req.prefill_done
        ]
        self.stats.decode_steps += 1
        self.stats.active_slot_steps += len(active)
        self.stats.idle_slot_steps += self.pcfg.max_slots - len(active)
        for slot, req in active:
            tok = int(self._pick_one(logits[slot], req, len(req.output)))
            req.output.append(tok)
            self._lengths[slot] += 1
            req.verified_len = int(self._lengths[slot])
            req.drafted_len = max(req.drafted_len, req.verified_len)
            self._last_tok[slot] = tok
            self.stats.generated_tokens += 1
            self._emit("DECODE", req.rid, new_tokens=1, out_len=len(req.output))
            if req.is_done():
                self._release(req, step)
                finished.append(req)
        return finished

    def _do_verify(self, step: int) -> List[Request]:
        """One speculative verify step: draft k tokens per active slot,
        score all k+1 positions in ONE batched `paged_score_tokens`
        call, commit the longest agreed prefix plus the target's own
        correction/bonus token, and roll the logical length back over
        the rejected tail.

        Greedy acceptance: with targets ``t_i = argmax(logits[:, i])``
        and drafts ``d_1..d_k``, accept while ``d_{i+1} == t_i`` — the
        committed tokens ``t_0..t_a`` are exactly what sequential
        one-token decode would have produced, so spec_k only changes
        throughput, never the stream.
        """
        self._flush_scrubs()
        k = self.pcfg.spec_k
        w = k + 1
        m = self.pcfg.max_slots
        active = [
            (slot, req)
            for slot, req in self.scheduler.running.items()
            if req.prefill_done
        ]
        tokens = np.zeros((m, w), np.int32)
        tokens[:, 0] = self._last_tok
        drafts: Dict[int, List[int]] = {}
        propose_hist = self.metrics.histogram("serve_draft_propose_seconds")
        for slot, req in active:
            td = time.perf_counter()
            d = self.drafter.propose(req, k)
            propose_hist.observe(time.perf_counter() - td)
            assert len(d) == k, (len(d), k)
            drafts[slot] = d
            tokens[slot, 1:] = d
        with self._mesh_ctx(), phase_annotation("serve.verify", self._profile):
            logits, (self._k_pool, self._v_pool) = self._score(
                self.params,
                jnp.asarray(tokens),
                self._k_pool,
                self._v_pool,
                jnp.asarray(self._tables),
                jnp.asarray(self._lengths),
            )
        logits = np.asarray(logits, np.float32)  # [m, w, V]

        finished = []
        self.stats.decode_steps += 1
        self.stats.spec_steps += 1
        self.stats.active_slot_steps += len(active)
        self.stats.idle_slot_steps += m - len(active)
        for slot, req in active:
            base = int(self._lengths[slot])
            req.drafted_len = max(req.drafted_len, base + w)
            targets = np.argmax(logits[slot], axis=-1)
            d = drafts[slot]
            a = 0
            while a < k and d[a] == int(targets[a]):
                a += 1
            self.stats.drafted_tokens += k
            self.stats.accepted_tokens += a
            committed = 0
            for t in targets[: a + 1]:
                req.output.append(int(t))
                committed += 1
                self.stats.generated_tokens += 1
                self.stats.spec_committed_tokens += 1
                if req.is_done():  # stop_token / max_new hit mid-burst
                    break
            self._lengths[slot] = base + committed
            self._last_tok[slot] = req.output[-1]
            self.scheduler.rollback(req, base + committed)
            self._emit(
                "VERIFY",
                req.rid,
                k=k,
                accepted=a,
                new_tokens=committed,
                out_len=len(req.output),
            )
            if req.is_done():
                self._release(req, step)
                finished.append(req)
        return finished

    def _grow_active(self, step: int) -> None:
        """On-demand capacity phase (preemption="recompute"), run just
        before the decode/verify call: every fully-prefilled sequence
        must own blocks for the positions this step writes — one for
        plain decode, spec_k + 1 for a verify burst.  Growth runs most
        deserving first, so under pool pressure the victims are exactly
        the least deserving sequences (possibly a grower itself, which
        is then parked and dropped from this step's batch)."""
        w = self.pcfg.spec_k + 1 if self.pcfg.spec_k else 1
        active = sorted(
            (r for r in self.scheduler.running.values() if r.prefill_done),
            key=Scheduler.deserving,
            reverse=True,
        )
        for req in active:
            if req.state is not RequestState.RUNNING:
                continue  # evicted by a more deserving grower above
            before = len(req.alloc.blocks)
            if self.scheduler.grow(
                req, req.verified_len + w, self._on_preempt, step
            ):
                self._tables[req.slot] = req.alloc.table_row(
                    self.max_blocks_per_seq
                )
                after = len(req.alloc.blocks)
                if after != before:
                    self._emit(
                        "GROW",
                        req.rid,
                        new_blocks=after - before,
                        blocks=after,
                    )

    def _on_preempt(self, req: Request, slot: int, scrub: List[int]) -> None:
        """Scheduler preemption callback: scrub every block the victim
        ever wrote (committed K/V included — the resume recomputes it,
        so nothing of the evicted sequence may survive in the pool),
        reset the victim's decode-slot state, and tell a stateful
        drafter its context bookkeeping is void."""
        if scrub:
            self._scrub(scrub)
        self._tables[slot] = SCRATCH_BLOCK
        self._lengths[slot] = 0
        self._last_tok[slot] = 0
        if req in self._prefilling:  # evicted mid-chunk-prefill
            self._prefilling.remove(req)
        if self.drafter is not None:
            hook = getattr(self.drafter, "on_preempt", None)
            if hook is not None:
                hook(req)
        self.stats.preemptions += 1
        self._emit(
            "PREEMPT",
            req.rid,
            blocks_freed=len(scrub),
            preempt_count=req.preempt_count,
            out_len=len(req.output),
        )

    def _cancel(self, req: Request, step: int) -> None:
        was_running = req.state is RequestState.RUNNING
        slot = req.slot
        stale = self.scheduler.cancel(req, step)
        if was_running:
            if stale:
                self._scrub(stale)
            self._tables[slot] = SCRATCH_BLOCK
            self._lengths[slot] = 0
            self._last_tok[slot] = 0
            if req in self._prefilling:
                self._prefilling.remove(req)

    def _release(self, req: Request, step: int) -> None:
        slot = req.slot
        stale = self.scheduler.retire(req, step)
        if stale:
            self._scrub(stale)
        self._tables[slot] = SCRATCH_BLOCK
        self._lengths[slot] = 0
        self._last_tok[slot] = 0
        self._emit("FINISH", req.rid, out_len=len(req.output))

    def _scrub(self, blocks: List[int]) -> None:
        """Queue freed blocks that hold written-but-never-committed K/V
        (rolled-back speculative tails, prefill padding) for zeroing,
        so a future owner can never attend over a previous sequence's
        stale keys — the length masks make such reads unreachable
        today, but the free list is the trust boundary and scrubbed
        blocks keep it airtight against any future mask/length
        accounting bug.  Queued blocks are zeroed in one batched
        scatter (:meth:`_flush_scrubs`) before the next pool write:
        every compute helper flushes first, so a queued block can never
        be reallocated *and written* ahead of its scrub."""
        self._scrub_pending.extend(blocks)

    def _flush_scrubs(self) -> None:
        """Zero every pending freed block — retires, preempts, cancels,
        spec rollbacks and prefix-cache evictions accumulated since the
        last flush — in ONE padded scatter call, instead of one jitted
        dispatch per event.  The id row is padded with the scratch
        block to the next multiple of max_blocks_per_seq so flushes
        share a handful of compiles (re-zeroing scratch is harmless)."""
        self._scrub_pending.extend(self.allocator.drain_evicted())
        if not self._scrub_pending:
            return
        blocks, self._scrub_pending = self._scrub_pending, []
        w = self.max_blocks_per_seq
        n = -(-len(blocks) // w) * w
        ids = np.full((n,), SCRATCH_BLOCK, np.int32)
        ids[: len(blocks)] = blocks
        with self._mesh_ctx(), phase_annotation("serve.scrub", self._profile):
            self._k_pool, self._v_pool = self._scrub_fn(
                self._k_pool, self._v_pool, jnp.asarray(ids)
            )

    def _apply_cow(self, req: Request) -> None:
        """Copy-on-write before a shared tail block absorbs writes: the
        one cache-hit block this sequence must write into (a fully-hit
        block whose last token is recomputed for logits — ``cached_len``
        was capped mid-block) is duplicated into the private block
        allocated in its place, then the pin on the shared source is
        dropped.  Runs before the suffix prefill touches the pool."""
        src = req.cow_src
        assert src is not None
        self._flush_scrubs()
        dst = req.alloc.blocks[req.cached_len // self.pcfg.block_size]
        with self._mesh_ctx(), phase_annotation("serve.cow", self._profile):
            self._k_pool, self._v_pool = self._cow_fn(
                self._k_pool, self._v_pool, jnp.int32(src), jnp.int32(dst)
            )
        req.cow_src = None
        self._scrub(self.allocator.release([src]))

    def _pick_one(self, logits_row, req: Request, token_idx: int):
        if self.pcfg.temperature <= 0:
            # host-side argmax: logits are already materialized as numpy
            # in the decode loop; a jnp.argmax here would re-upload every
            # row and add a device round-trip per slot per step
            return int(np.argmax(np.asarray(logits_row)))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.pcfg.seed), req.rid),
            token_idx,
        )
        return int(
            jax.random.categorical(
                key, jnp.asarray(logits_row) / self.pcfg.temperature
            )
        )
