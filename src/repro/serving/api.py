"""The public serving API: options, engine factory, request handles.

This module is the redesigned front door for serving — everything an
application needs lives behind four names:

* :class:`ServeOptions` — ONE options dataclass replacing the split
  ``ServeConfig`` (static engine) / ``PagedServeConfig`` (continuous
  engine) pair.  Options are grouped per-request / sampling / engine /
  observability; :meth:`ServeOptions.paged` and
  :meth:`ServeOptions.static` project onto the legacy configs (which
  remain the engines' internal representation), and
  :meth:`ServeOptions.from_legacy` lifts an old config into options
  with a :class:`DeprecationWarning` so existing call sites keep
  working while they migrate.
* :func:`build_engine` — family-aware factory: ``engine="auto"`` picks
  the continuous-batching engine for families with a paged KV layout
  (dense / moe) and the static engine otherwise (ssm / hybrid / encdec
  / vlm caches are not paged).
* :class:`SubmitHandle` — what ``ContinuousBatchingEngine.submit``
  returns: a future-like view of one request exposing ``result()`` /
  ``cancel()`` / ``trace()`` / ``breakdown()`` and delegating every
  ``Request`` attribute, so pre-redesign code that treated the return
  value as a ``Request`` is untouched.
* ``engine.stream(prompt, ...)`` — incremental tokens + trace events
  (defined on the engine; re-exported story documented here).

Typical use::

    from repro.serving import ServeOptions, build_engine

    opts = ServeOptions(max_new_tokens=64, prefill_chunk=16, spec_k=4)
    eng = build_engine(cfg, opts)
    handle = eng.submit(prompt, max_new_tokens=64)
    tokens = handle.result()          # drives the engine to completion
    print(handle.breakdown())         # queue/prefill/decode/parked split
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

from repro.configs.base import ModelConfig

from .engine import ContinuousBatchingEngine, Engine, PagedServeConfig, ServeConfig
from .scheduler import Request, RequestState

#: families served by the continuous-batching engine under engine="auto"
PAGED_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class ServeOptions:
    """Unified serving options (supersedes ServeConfig/PagedServeConfig).

    Field groups:

    * request defaults — per-request knobs ``submit()`` also accepts;
      values here are the defaults used by ``stream()`` and the
      launcher.
    * sampling — shared by both engines.
    * engine — capacity/parallelism/speculation/preemption; only
      meaningful for the continuous engine (the static engine ignores
      them, matching the old ServeConfig surface).
    * observability — tracing / profiling / step timing.
    """

    # -- request defaults --------------------------------------------------
    max_new_tokens: int = 16
    stop_token: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None

    # -- sampling ----------------------------------------------------------
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0

    # -- engine (continuous batching) --------------------------------------
    engine: str = "auto"  # "auto" | "continuous" | "static"
    block_size: int = 16
    num_blocks: int = 128
    max_slots: int = 4
    max_seq_len: int = 256
    cache_dtype: str = "bfloat16"
    use_kernel: Optional[bool] = None
    tp: int = 1
    prefill_chunk: int = 0
    prequantize: bool = False
    spec_k: int = 0
    spec_draft: object = "ngram"
    preemption: str = "off"
    prefix_cache: bool = False  # content-addressed KV reuse across requests
    clock: Optional[object] = None

    # -- observability -----------------------------------------------------
    trace: bool = True
    profile: bool = False
    time_steps: bool = False  # static engine: sync + time each step

    def paged(self) -> PagedServeConfig:
        """Project onto the continuous engine's internal config."""
        return PagedServeConfig(
            block_size=self.block_size,
            num_blocks=self.num_blocks,
            max_slots=self.max_slots,
            max_seq_len=self.max_seq_len,
            temperature=self.temperature,
            seed=self.seed,
            cache_dtype=self.cache_dtype,
            use_kernel=self.use_kernel,
            tp=self.tp,
            prefill_chunk=self.prefill_chunk,
            prequantize=self.prequantize,
            spec_k=self.spec_k,
            spec_draft=self.spec_draft,
            preemption=self.preemption,
            prefix_cache=self.prefix_cache,
            clock=self.clock,
            trace=self.trace,
            profile=self.profile,
        )

    def static(self) -> ServeConfig:
        """Project onto the static engine's internal config."""
        return ServeConfig(
            max_new_tokens=self.max_new_tokens,
            temperature=self.temperature,
            seed=self.seed,
            time_steps=self.time_steps,
        )

    def submit_kwargs(self) -> dict:
        """The per-request defaults as ``submit()`` keyword arguments."""
        return dict(
            max_new_tokens=self.max_new_tokens,
            stop_token=self.stop_token,
            priority=self.priority,
            deadline_s=self.deadline_s,
        )

    @classmethod
    def from_legacy(cls, cfg, **overrides) -> "ServeOptions":
        """Lift a legacy ``ServeConfig`` / ``PagedServeConfig`` into
        options, warning once per call site.  ``overrides`` are applied
        on top (e.g. ``from_legacy(pcfg, max_new_tokens=64)``)."""
        if isinstance(cfg, PagedServeConfig):
            fields = {
                f.name: getattr(cfg, f.name)
                for f in dataclasses.fields(PagedServeConfig)
            }
            fields["engine"] = "continuous"
        elif isinstance(cfg, ServeConfig):
            fields = dict(
                max_new_tokens=cfg.max_new_tokens,
                temperature=cfg.temperature,
                seed=cfg.seed,
                time_steps=cfg.time_steps,
                engine="static",
            )
        else:
            raise TypeError(
                f"expected ServeConfig or PagedServeConfig, got {type(cfg)!r}"
            )
        warnings.warn(
            f"{type(cfg).__name__} is deprecated as a public surface; "
            "construct repro.serving.ServeOptions instead (this shim maps "
            "fields 1:1 and will keep working)",
            DeprecationWarning,
            stacklevel=2,
        )
        fields.update(overrides)
        return cls(**fields)


class SubmitHandle:
    """Future-like view of one submitted request.

    Returned by ``ContinuousBatchingEngine.submit``.  Every ``Request``
    attribute (``rid``, ``state``, ``output``, ``finished_step``, ...)
    is delegated, so code written against the old Request-returning
    ``submit`` runs unchanged; new code gets:

    * :meth:`result` — drive the engine until this request reaches a
      terminal state, then return its committed tokens;
    * :meth:`cancel` — client-side abort (keeps committed output);
    * :meth:`trace` — this request's trace events (empty when tracing
      is off);
    * :meth:`breakdown` — queue/prefill/decode/parked latency split
      derived from the trace (None when tracing is off).
    """

    __slots__ = ("_engine", "_request")

    def __init__(self, engine: ContinuousBatchingEngine, request: Request):
        self._engine = engine
        self._request = request

    @property
    def request(self) -> Request:
        """The underlying scheduler Request (escape hatch)."""
        return self._request

    def result(self) -> List[int]:
        """Block (drive ``engine.step()``) until this request finishes
        or is cancelled; returns the committed output tokens.  Other
        queued requests keep making progress — this drives the shared
        engine loop, it does not serialize the engine to one request."""
        while self._request.state not in (
            RequestState.FINISHED,
            RequestState.CANCELLED,
        ):
            self._engine.step()
        return self._request.output

    def cancel(self) -> None:
        self._engine.cancel(self._request)

    def trace(self) -> list:
        """This request's TraceEvents, in emission order."""
        if self._engine.trace is None:
            return []
        return self._engine.trace.request_events(self._request.rid)

    def breakdown(self):
        """Latency split (RequestBreakdown) once terminal; None when
        tracing is off."""
        if self._engine.trace is None:
            return None
        return self._engine.trace.breakdown(self._request.rid)

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._request, name)

    def __repr__(self) -> str:
        r = self._request
        return (
            f"SubmitHandle(rid={r.rid}, state={r.state.name}, "
            f"out={len(r.output)}/{r.max_new_tokens})"
        )


def build_engine(
    cfg: ModelConfig, opts: Optional[ServeOptions] = None, params=None, key=None
):
    """Build the right engine for ``cfg`` under ``opts``.

    ``opts.engine``: ``"continuous"`` forces the paged engine (raises
    for families without a paged KV layout), ``"static"`` forces the
    static batcher, ``"auto"`` (default) picks continuous for
    :data:`PAGED_FAMILIES` and static otherwise.
    """
    opts = opts or ServeOptions()
    kind = opts.engine
    if kind == "auto":
        kind = "continuous" if cfg.family in PAGED_FAMILIES else "static"
    if kind == "continuous":
        return ContinuousBatchingEngine(cfg, params=params, key=key, pcfg=opts.paged())
    if kind == "static":
        return Engine(cfg, params=params, key=key, prequantize=opts.prequantize)
    raise ValueError(
        f"unknown engine kind {opts.engine!r}; use 'auto', 'continuous' or 'static'"
    )
