"""Paged KV cache: fixed-size blocks + a free-list allocator.

Replaces the monolithic per-prompt [L, B, S_max, kv, hd] caches with a
single shared pool of [L, num_blocks, block_size, kv, hd] and a block
table per sequence, vLLM-style:

* no per-request padding to a global max length — a sequence holds
  exactly ceil(len / block_size) blocks;
* admission control becomes arithmetic on the free list, so the
  scheduler can decide "does this request fit?" without touching
  device memory;
* retiring a sequence is O(1): return its blocks to the free list.

Block 0 is reserved as a scratch block: inactive batch slots in the
jitted decode step point their block tables at it, so their (masked,
ignored) writes never corrupt a live sequence.

Prefix caching (``prefix_cache=True``) makes the allocator
content-addressed on top of the free list: every full block a prefill
writes can be *registered* under the chain hash of its token prefix
(``hash(parent_hash, block_tokens)``), per-block refcounts track how
many live sequences share a block, and blocks whose refcount drops to
zero while registered are parked on an LRU list instead of freed —
still valid cache, reclaimed (evicted, then scrubbed by the engine,
then freed) only under pool pressure.  Admission walks a new prompt's
full blocks through the hash map and reuses every leading hit, so only
the miss suffix is prefilled.  Three rules keep the pool sound:

* a block is never scrubbed while its refcount is > 0;
* a sequence never writes into a block it shares (refcount > 1) — the
  one case where a hit block must absorb writes (a fully-cached,
  block-aligned prompt still has to recompute its last token for
  logits) is resolved by copy-on-write into a private block;
* eviction strictly precedes reuse: an evicted block is unregistered,
  reported via ``drain_evicted`` for scrubbing, and only then eligible
  for reallocation.

Device storage lives in the engine as a pair of jnp arrays returned by
`ModelAPI.paged_pool_init`; this module is the host-side bookkeeping.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence


SCRATCH_BLOCK = 0  # pool index never handed out by the allocator


class OutOfBlocksError(RuntimeError):
    """Raised on allocation from an exhausted pool (callers that want
    to wait instead should check `can_allocate` first)."""


class BlockAllocator:
    """Free-list allocator over pool indices [1, num_blocks).

    Index 0 is the reserved scratch block (see module docstring).
    With ``prefix_cache=True`` the allocator additionally keeps
    per-block refcounts, the chain-hash -> block map and the LRU of
    unreferenced-but-cached blocks; with it off (the default) every
    cache method degenerates to a no-op and ``allocate``/``release``
    behave exactly like the historical allocate/free pair.
    """

    def __init__(self, num_blocks: int, block_size: int, prefix_cache: bool = False):
        assert num_blocks >= 2, "need at least one allocatable block"
        assert block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self._free: deque[int] = deque(range(1, num_blocks))
        # content-addressed state (all empty / zero while prefix_cache
        # is off, so the legacy invariants hold unchanged)
        self._refcount: List[int] = [0] * num_blocks
        self._block_hash: List[Optional[int]] = [None] * num_blocks
        self._hash_to_block: Dict[int, int] = {}
        # refcount-0 registered blocks, oldest-released first (the
        # eviction order); values unused, OrderedDict for O(1) touch
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # evicted blocks not yet scrubbed — the engine drains this and
        # zeroes them before any jitted call can touch the pool again
        self._evicted_dirty: List[int] = []
        # hit-rate observability, read live by the engine's metric
        # sources (counts are in BLOCKS except tokens_saved)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.tokens_saved = 0
        self.cow_copies = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_cached_idle(self) -> int:
        """Registered blocks no live sequence references (the LRU) —
        reusable as cache hits, reclaimable via eviction."""
        return len(self._lru)

    @property
    def num_available(self) -> int:
        """Blocks an ``allocate`` call could produce: the free list
        plus everything evictable from the cache LRU."""
        return len(self._free) + len(self._lru)

    @property
    def num_cached(self) -> int:
        """Blocks holding registered prefix-cache content (referenced
        or idle) — the cached-block occupancy gauge."""
        return len(self._hash_to_block)

    @property
    def num_referenced(self) -> int:
        """Blocks held (refcount > 0) by live sequences."""
        return sum(1 for rc in self._refcount if rc > 0)

    @property
    def num_used(self) -> int:
        """Blocks not on the free list (scratch excluded): owned by
        live sequences or parked as idle cache."""
        return self.num_blocks - 1 - len(self._free)

    def refcount(self, block: int) -> int:
        return self._refcount[block]

    def utilization(self) -> float:
        """Fraction of the allocatable pool in use — the occupancy
        gauge the observability layer samples per event/step."""
        allocatable = self.num_blocks - 1
        return self.num_used / allocatable if allocatable else 0.0

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold n_tokens cache entries."""
        return max(1, -(-n_tokens // self.block_size))

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_available

    def allocate(self, n_blocks: int) -> List[int]:
        """Pop ``n_blocks`` from the free list, evicting idle cached
        blocks (LRU-first) to cover any shortfall.  Every returned
        block starts with refcount 1 (owned by the caller)."""
        if not self.can_allocate(n_blocks):
            raise OutOfBlocksError(
                f"requested {n_blocks} blocks, {self.num_free} free "
                f"+ {self.num_cached_idle} evictable"
            )
        while len(self._free) < n_blocks:
            self._evict_one()
        out = [self._free.popleft() for _ in range(n_blocks)]
        for b in out:
            self._refcount[b] = 1
        return out

    def _evict_one(self) -> None:
        """Reclaim the least-recently-released idle cached block:
        unregister it, mark it dirty (the engine scrubs it before the
        next jitted call) and return it to the free list."""
        block, _ = self._lru.popitem(last=False)
        self._unregister(block)
        self._free.append(block)
        self._evicted_dirty.append(block)
        self.evictions += 1

    def drain_evicted(self) -> List[int]:
        """Evicted-but-unscrubbed blocks since the last drain.  The
        engine folds these into its batched scrub before any compute
        touches the pool (eviction -> scrub -> reuse ordering)."""
        out, self._evicted_dirty = self._evicted_dirty, []
        return out

    def free(self, blocks: Sequence[int]) -> None:
        """Force blocks back onto the free list (the raw primitive —
        refcount-aware callers use :meth:`release`).  Rejects
        out-of-range ids, the scratch block, double frees and blocks
        other sequences still share, instead of silently corrupting
        the free list."""
        for b in blocks:
            if not (0 <= b < self.num_blocks):
                raise ValueError(
                    f"free of out-of-range block id {b} "
                    f"(pool blocks are 0..{self.num_blocks - 1})"
                )
            if b == SCRATCH_BLOCK:
                raise ValueError("free of reserved scratch block 0")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            if self._refcount[b] > 1:
                raise ValueError(
                    f"free of shared block {b} (refcount "
                    f"{self._refcount[b]}); use release()"
                )
            if self._block_hash[b] is not None:
                self._unregister(b)
            self._refcount[b] = 0
            self._free.append(b)

    def release(self, blocks: Sequence[int]) -> List[int]:
        """Drop one reference per block.  A block whose refcount hits
        zero is parked on the cache LRU when registered, freed
        otherwise.  Returns the blocks that reached the free list —
        the caller must scrub any of them that were ever written."""
        freed: List[int] = []
        for b in blocks:
            rc = self._refcount[b]
            if rc <= 0:
                raise ValueError(f"release of unreferenced block {b}")
            self._refcount[b] = rc - 1
            if rc > 1:
                continue
            if self._block_hash[b] is not None:
                self._lru[b] = None
                self._lru.move_to_end(b)
            else:
                self._free.append(b)
                freed.append(b)
        return freed

    # -- content addressing ------------------------------------------------

    def _chain_hashes(self, tokens: Sequence[int]) -> List[int]:
        """Chain hash per FULL block of ``tokens``:
        ``h_i = hash((h_{i-1}, block_i_tokens))`` — position-dependent
        by construction, so equal blocks under different prefixes never
        collide into one pool block."""
        out: List[int] = []
        h = 0
        bs = self.block_size
        for i in range(len(tokens) // bs):
            h = hash((h, tuple(tokens[i * bs : (i + 1) * bs])))
            out.append(h)
        return out

    def match_prefix(self, tokens: Sequence[int]) -> List[int]:
        """Peek (no refcount change): the cached blocks holding the
        longest full-block prefix of ``tokens``, in logical order."""
        if not self.prefix_cache:
            return []
        out: List[int] = []
        for h in self._chain_hashes(tokens):
            b = self._hash_to_block.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def acquire(self, blocks: Sequence[int]) -> None:
        """Take one reference per (registered) block — a cache hit.
        Idle blocks leave the LRU; they are no longer evictable."""
        for b in blocks:
            if self._refcount[b] == 0:
                assert b in self._lru, f"acquire of unregistered idle block {b}"
                del self._lru[b]
            self._refcount[b] += 1

    def register(self, tokens: Sequence[int], blocks: Sequence[int]) -> None:
        """Publish a prefilled sequence's FULL token blocks into the
        hash map (called once prefill has actually written them — a
        mapping must never race ahead of pool content).  First writer
        wins: hashes already mapped keep their canonical block."""
        if not self.prefix_cache:
            return
        for h, b in zip(self._chain_hashes(tokens), blocks):
            if h in self._hash_to_block:
                continue  # an identical prefix is already canonical
            assert self._block_hash[b] is None, (
                f"block {b} already registered under another hash"
            )
            self._hash_to_block[h] = b
            self._block_hash[b] = h

    def _unregister(self, block: int) -> None:
        h = self._block_hash[block]
        if h is not None:
            del self._hash_to_block[h]
            self._block_hash[block] = None
        self._lru.pop(block, None)


@dataclasses.dataclass
class SequenceAllocation:
    """The blocks one running sequence owns, in logical order: block i
    holds cache positions [i*block_size, (i+1)*block_size)."""

    blocks: List[int]
    block_size: int

    def table_row(self, width: int) -> List[int]:
        """Block table row padded to the engine's static width with the
        scratch block (those entries are masked by the length)."""
        assert len(self.blocks) <= width, (len(self.blocks), width)
        return self.blocks + [SCRATCH_BLOCK] * (width - len(self.blocks))

    def capacity(self) -> int:
        return len(self.blocks) * self.block_size

    def grow(self, blocks: List[int]) -> None:
        """Append freshly-allocated blocks (on-demand growth under
        preemptive scheduling): the new blocks extend the sequence's
        logical position range past the previous capacity."""
        assert SCRATCH_BLOCK not in blocks
        assert not set(blocks) & set(self.blocks), "grow with owned block"
        self.blocks.extend(blocks)

    def blocks_covering(self, start: int, stop: int) -> List[int]:
        """Blocks holding logical positions [start, stop) — the
        truncate/rollback primitive.  Speculative decoding writes k+1
        positions per verify step and then rolls the logical length
        back over the rejected tail; the blocks named here still hold
        that stale (never-committed) K/V and must be scrubbed before
        they are handed to another sequence."""
        if stop <= start:
            return []
        assert stop <= self.capacity(), (start, stop, self.capacity())
        lo = start // self.block_size
        hi = (stop - 1) // self.block_size
        return self.blocks[lo : hi + 1]


def padded_prompt_len(prompt_len: int, block_size: int) -> int:
    """Prompt length right-padded to a whole number of blocks (the
    prefill bucket — one XLA compile per distinct value)."""
    return max(1, -(-prompt_len // block_size)) * block_size
