"""Paged KV cache: fixed-size blocks + a free-list allocator.

Replaces the monolithic per-prompt [L, B, S_max, kv, hd] caches with a
single shared pool of [L, num_blocks, block_size, kv, hd] and a block
table per sequence, vLLM-style:

* no per-request padding to a global max length — a sequence holds
  exactly ceil(len / block_size) blocks;
* admission control becomes arithmetic on the free list, so the
  scheduler can decide "does this request fit?" without touching
  device memory;
* retiring a sequence is O(1): return its blocks to the free list.

Block 0 is reserved as a scratch block: inactive batch slots in the
jitted decode step point their block tables at it, so their (masked,
ignored) writes never corrupt a live sequence.

Device storage lives in the engine as a pair of jnp arrays returned by
`ModelAPI.paged_pool_init`; this module is the host-side bookkeeping.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List


SCRATCH_BLOCK = 0  # pool index never handed out by the allocator


class OutOfBlocksError(RuntimeError):
    """Raised on allocation from an exhausted pool (callers that want
    to wait instead should check `can_allocate` first)."""


class BlockAllocator:
    """Free-list allocator over pool indices [1, num_blocks).

    Index 0 is the reserved scratch block (see module docstring).
    """

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least one allocatable block"
        assert block_size >= 1
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque[int] = deque(range(1, num_blocks))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        """Blocks currently owned by live sequences (scratch excluded)."""
        return self.num_blocks - 1 - len(self._free)

    def utilization(self) -> float:
        """Fraction of the allocatable pool in use — the occupancy
        gauge the observability layer samples per event/step."""
        allocatable = self.num_blocks - 1
        return self.num_used / allocatable if allocatable else 0.0

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold n_tokens cache entries."""
        return max(1, -(-n_tokens // self.block_size))

    def can_allocate(self, n_blocks: int) -> bool:
        return n_blocks <= self.num_free

    def allocate(self, n_blocks: int) -> List[int]:
        if not self.can_allocate(n_blocks):
            raise OutOfBlocksError(f"requested {n_blocks} blocks, {self.num_free} free")
        return [self._free.popleft() for _ in range(n_blocks)]

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            assert b != SCRATCH_BLOCK, "scratch block is never allocated"
            assert b not in self._free, f"double free of block {b}"
            self._free.append(b)


@dataclasses.dataclass
class SequenceAllocation:
    """The blocks one running sequence owns, in logical order: block i
    holds cache positions [i*block_size, (i+1)*block_size)."""

    blocks: List[int]
    block_size: int

    def table_row(self, width: int) -> List[int]:
        """Block table row padded to the engine's static width with the
        scratch block (those entries are masked by the length)."""
        assert len(self.blocks) <= width, (len(self.blocks), width)
        return self.blocks + [SCRATCH_BLOCK] * (width - len(self.blocks))

    def capacity(self) -> int:
        return len(self.blocks) * self.block_size

    def grow(self, blocks: List[int]) -> None:
        """Append freshly-allocated blocks (on-demand growth under
        preemptive scheduling): the new blocks extend the sequence's
        logical position range past the previous capacity."""
        assert SCRATCH_BLOCK not in blocks
        assert not set(blocks) & set(self.blocks), "grow with owned block"
        self.blocks.extend(blocks)

    def blocks_covering(self, start: int, stop: int) -> List[int]:
        """Blocks holding logical positions [start, stop) — the
        truncate/rollback primitive.  Speculative decoding writes k+1
        positions per verify step and then rolls the logical length
        back over the rejected tail; the blocks named here still hold
        that stale (never-committed) K/V and must be scrubbed before
        they are handed to another sequence."""
        if stop <= start:
            return []
        assert stop <= self.capacity(), (start, stop, self.capacity())
        lo = start // self.block_size
        hi = (stop - 1) // self.block_size
        return self.blocks[lo : hi + 1]


def padded_prompt_len(prompt_len: int, block_size: int) -> int:
    """Prompt length right-padded to a whole number of blocks (the
    prefill bucket — one XLA compile per distinct value)."""
    return max(1, -(-prompt_len // block_size)) * block_size
