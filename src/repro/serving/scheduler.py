"""Request lifecycle + admission control for continuous batching.

A `Request` moves WAITING -> RUNNING -> FINISHED.  Every engine step the
`Scheduler` retires finished sequences (returning their blocks to the
free list) and admits waiting ones FCFS while both a batch slot and
enough KV blocks are available.

Admission reserves blocks for the WHOLE lifetime up front
(prompt + max_new_tokens), so an admitted sequence can never run out of
cache mid-decode and no preemption machinery is needed — the right
trade at this scale; swap-out/recompute preemption is a later PR.

Chunked prefill does not change admission: a request still reserves all
its blocks when admitted, and `prefill_pos` tracks how much of the
prompt has been written so the engine knows when the sequence may start
decoding.  The scheduler itself is sharding-agnostic — block tables and
the free list are host-side state, replicated under any mesh.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Dict, List, Optional

from .kv_cache import BlockAllocator, SequenceAllocation, padded_prompt_len


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    arrival_step: engine step at which the request becomes visible to
    the scheduler (simulates staggered client arrivals; 0 = present
    from the start).  stop_token: optional early-termination token id.
    """

    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    arrival_step: int = 0
    stop_token: Optional[int] = None

    # lifecycle (managed by the scheduler/engine)
    state: RequestState = RequestState.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    alloc: Optional[SequenceAllocation] = None
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    prefill_pos: int = 0  # prompt tokens already written to the KV pool
    # speculative-decoding length bookkeeping.  verified_len counts the
    # COMMITTED cache positions (what attention masks trust);
    # drafted_len is the high-water mark of positions ever written —
    # prefill padding and rejected draft tails push it past
    # verified_len, and that [verified_len, drafted_len) range is the
    # stale K/V scrubbed at retirement.  Invariant at every step:
    # verified_len <= drafted_len <= alloc.capacity().
    verified_len: int = 0
    drafted_len: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def prefill_done(self) -> bool:
        """True once the whole prompt is cached (the sequence may decode)."""
        return self.prefill_pos >= self.prompt_len

    def is_done(self) -> bool:
        if len(self.output) >= self.max_new_tokens:
            return True
        return (
            self.stop_token is not None
            and len(self.output) > 0
            and self.output[-1] == self.stop_token
        )


class Scheduler:
    """FCFS admission over a fixed slot count and a shared block pool.

    spec_k > 0 turns on worst-case burst reservation for speculative
    decoding: every verify step may write k+1 positions beyond the
    committed length before acceptance is known, so admission reserves
    room for the deepest possible burst — the write must never escape
    the sequence's own blocks even when every draft is rejected.
    """

    def __init__(
        self,
        allocator: BlockAllocator,
        max_slots: int,
        max_seq_len: int,
        spec_k: int = 0,
    ):
        self.allocator = allocator
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.spec_k = spec_k
        self.waiting: deque[Request] = deque()
        self.running: Dict[int, Request] = {}  # slot -> request
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # -- bookkeeping -------------------------------------------------------

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new_tokens
        if total > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"engine max_seq_len={self.max_seq_len}"
            )
        need = self.blocks_needed(req)
        pool = self.allocator.num_blocks - 1  # block 0 is reserved
        if need > pool:
            # reject now: admit() could never satisfy it and the engine
            # loop would spin forever on a permanently-waiting head
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks but the pool "
                f"only has {pool}; raise num_blocks or shrink the request"
            )
        self.waiting.append(req)

    def blocks_needed(self, req: Request) -> int:
        """Whole-lifetime reservation: padded prompt blocks plus room
        for every decoded token's KV (the last sampled token is never
        written back, hence the -1).

        Burst math under spec_k: the deepest verify starts at committed
        length prompt + max_new - 2 (one more commit would finish the
        request) and writes k+1 positions, so the top written position
        is prompt + max_new - 2 + spec_k — reserve
        prompt + max_new - 1 + spec_k positions.  A max_new == 1
        request finishes at prefill and never verifies, so it carries
        no burst headroom."""
        bs = self.allocator.block_size
        prompt_pad = padded_prompt_len(req.prompt_len, bs)
        total_positions = max(prompt_pad, req.prompt_len + req.max_new_tokens - 1)
        if self.spec_k and req.max_new_tokens > 1:
            total_positions = max(
                total_positions,
                req.prompt_len + req.max_new_tokens - 1 + self.spec_k,
            )
        return self.allocator.blocks_for(total_positions)

    # -- per-step scheduling ----------------------------------------------

    def admit(self, step: int) -> List[Request]:
        """Admit waiting requests (arrival-ordered) while a slot and
        blocks are free.  Strict FCFS: stop at the first request that
        does not fit, so a small late request cannot starve a big
        earlier one."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if req.arrival_step > step:
                break  # queue is arrival-ordered
            need = self.blocks_needed(req)
            if not self.allocator.can_allocate(need):
                break
            self.waiting.popleft()
            blocks = self.allocator.allocate(need)
            req.alloc = SequenceAllocation(blocks, self.allocator.block_size)
            req.slot = self._free_slots.pop()
            req.state = RequestState.RUNNING
            req.admitted_step = step
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def rollback(self, req: Request, committed_len: int) -> None:
        """Roll a sequence's logical length back after a verify step.

        The verify wrote K/V up to req.drafted_len; only
        ``committed_len`` positions were accepted.  The rejected tail's
        blocks stay owned — the next verify re-writes from
        committed_len, so within the sequence stale entries are always
        overwritten before the committed length reaches them — but the
        truncation must be recorded so retirement knows what to scrub.
        """
        assert req.state is RequestState.RUNNING
        assert req.verified_len <= committed_len <= req.drafted_len, (
            req.verified_len,
            committed_len,
            req.drafted_len,
        )
        assert req.drafted_len <= req.alloc.capacity(), (
            req.drafted_len,
            req.alloc.capacity(),
        )
        req.verified_len = committed_len

    def retire(self, req: Request, step: int) -> List[int]:
        """Retire a finished request, returning its blocks to the free
        list.  Returns the block ids still holding stale
        (written-but-never-committed) K/V — draft tails rolled back by
        `rollback`, prefill padding — which the engine must scrub
        before the allocator hands them to another sequence."""
        assert req.state is RequestState.RUNNING
        req.state = RequestState.FINISHED
        req.finished_step = step
        stale = req.alloc.blocks_covering(req.verified_len, req.drafted_len)
        self.allocator.free(req.alloc.blocks)
        req.alloc = None
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        return stale

    def has_work(self) -> bool:
        return bool(self.running) or bool(self.waiting)
