"""Request lifecycle + admission control for continuous batching.

A `Request` moves WAITING -> RUNNING -> FINISHED, with two extra
terminal/parking states: CANCELLED (deadline expiry or client abort)
and PREEMPTED (evicted under pool pressure, waiting to resume).

Two admission regimes, selected by ``preemption``:

* ``"off"`` (default, the PR 1-4 behavior): admission reserves blocks
  for the WHOLE lifetime up front (prompt + max_new_tokens, plus the
  worst-case speculative burst), so an admitted sequence can never run
  out of cache mid-decode and no preemption machinery runs.
* ``"recompute"``: admission allocates only what prefill needs (the
  block-padded committed context) and sequences grow on demand, one
  block at a time, as they decode.  Under pool pressure the scheduler
  preempts a victim — the least *deserving* running request, i.e.
  lowest ``priority`` first, then latest ``arrival_step``, then
  highest rid — releasing ALL its blocks (the engine scrubs every
  written one) and parking it in ``preempted``.  It resumes later by
  recomputing the K/V of its committed tokens (prompt + generated
  output) through the chunked-prefill path; because that recompute is
  deterministic, a resumed stream is greedy-token-identical to an
  uninterrupted run.

Deservingness is a total order (rid breaks every tie), which is what
rules out livelock: the most deserving unfinished request is never a
victim, always wins growth/admission contention, and therefore always
finishes — then the next one does, and so on.

``Request.deadline_s`` is a wall-clock budget measured from submit
time; the engine sweeps expired requests (waiting, running, preempted)
into CANCELLED at the top of every step.  The clock is injectable so
tests drive deadlines deterministically.

Chunked prefill does not change admission: a request reserves all the
blocks its (padded) prompt needs when admitted, and `prefill_pos`
tracks how much of the prompt has been written.  The scheduler itself
is sharding-agnostic — block tables and the free list are host-side
state, replicated under any mesh.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from .kv_cache import BlockAllocator, SequenceAllocation, padded_prompt_len


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    PREEMPTED = "preempted"
    CANCELLED = "cancelled"


# (victim, freed slot, block ids to scrub).  The engine's callback
# zeroes the scrubbed blocks and resets the victim's decode-slot state;
# scheduler-only callers (property tests) may pass None.
PreemptCallback = Callable[["Request", int, List[int]], None]


@dataclasses.dataclass
class Request:
    """One generation request.

    arrival_step: engine step at which the request becomes visible to
    the scheduler (simulates staggered client arrivals; 0 = present
    from the start).  stop_token: optional early-termination token id.
    priority: larger = more deserving (admission order and preemption
    immunity under ``preemption="recompute"``; ignored under FCFS).
    deadline_s: optional wall-clock budget from submit time — once
    exceeded the request is cancelled wherever it is (waiting, running
    or preempted), keeping whatever output it already committed.
    """

    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    arrival_step: int = 0
    stop_token: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None
    submit_time: float = 0.0  # clock() at submit (engine fills this in)

    # lifecycle (managed by the scheduler/engine)
    state: RequestState = RequestState.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    alloc: Optional[SequenceAllocation] = None
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1
    prefill_pos: int = 0  # prefill tokens already written to the KV pool
    # speculative-decoding length bookkeeping.  verified_len counts the
    # COMMITTED cache positions (what attention masks trust);
    # drafted_len is the high-water mark of positions ever written —
    # prefill padding and rejected draft tails push it past
    # verified_len, and that [verified_len, drafted_len) range is the
    # stale K/V scrubbed at retirement.  Invariant at every step:
    # verified_len <= drafted_len <= alloc.capacity().
    verified_len: int = 0
    drafted_len: int = 0
    # preemption bookkeeping.  resume_ctx freezes the token sequence a
    # resume must recompute (prompt + all committed output but the last
    # token, which is re-fed as the next decode input); it is None for
    # a never-preempted request.
    resume_ctx: Optional[List[int]] = None
    preempt_count: int = 0
    preempted_step: int = -1
    preempted_time: float = 0.0
    # prefix-cache bookkeeping.  cached_len counts the leading prefill
    # positions served from the content-addressed cache at the last
    # activation (prefill starts there instead of 0).  cow_src, when
    # set, names a SHARED cached block whose content the engine must
    # copy into this sequence's private tail block before prefill — the
    # copy-on-write case: a fully-cached block-aligned context still
    # recomputes its final token, and that write may not land in a
    # block other sequences reference.  The scheduler pins cow_src with
    # a reference until the engine copies (or the request is torn down).
    cached_len: int = 0
    cow_src: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def prefill_tokens(self) -> List[int]:
        """The tokens (re)prefill must write: the prompt, or — after a
        preemption — the frozen committed context."""
        return self.prompt if self.resume_ctx is None else self.resume_ctx

    @property
    def prefill_len(self) -> int:
        return len(self.prefill_tokens)

    @property
    def prefill_done(self) -> bool:
        """True once the whole prefill context is cached (the sequence
        may decode)."""
        return self.prefill_pos >= self.prefill_len

    @property
    def committed_len(self) -> int:
        """Committed tokens: prompt plus every generated token.  This
        is the per-request monotone quantity — preemption resets cache
        bookkeeping (verified_len/drafted_len) but NEVER this."""
        return self.prompt_len + len(self.output)

    def is_done(self) -> bool:
        if len(self.output) >= self.max_new_tokens:
            return True
        return (
            self.stop_token is not None
            and len(self.output) > 0
            and self.output[-1] == self.stop_token
        )


class Scheduler:
    """Admission over a fixed slot count and a shared block pool.

    FCFS with whole-lifetime reservation under ``preemption="off"``;
    deserving-ordered admission with on-demand growth and victim
    preemption under ``preemption="recompute"`` (see module docstring).

    spec_k > 0: under "off" it turns on worst-case burst reservation
    (every verify step may write k+1 positions beyond the committed
    length before acceptance is known); under "recompute" the same
    burst is satisfied by `grow` right before each verify step.
    """

    def __init__(
        self,
        allocator: BlockAllocator,
        max_slots: int,
        max_seq_len: int,
        spec_k: int = 0,
        preemption: str = "off",
        clock: Optional[Callable[[], float]] = None,
    ):
        if preemption not in ("off", "recompute"):
            raise ValueError(
                f"preemption={preemption!r}: expected 'off' or 'recompute'"
            )
        self.allocator = allocator
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.spec_k = spec_k
        self.preemption = preemption
        self.clock = clock if clock is not None else time.monotonic
        self.waiting: deque[Request] = deque()
        self.preempted: List[Request] = []
        self.running: Dict[int, Request] = {}  # slot -> request
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # -- bookkeeping -------------------------------------------------------

    @property
    def num_waiting(self) -> int:
        """Queue depth: submitted, not yet admitted (metrics gauge)."""
        return len(self.waiting)

    @property
    def num_preempted(self) -> int:
        """Parked depth: evicted, awaiting recompute-resume."""
        return len(self.preempted)

    @property
    def num_running(self) -> int:
        """Admitted sequences currently holding a decode slot."""
        return len(self.running)

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new_tokens
        if total > self.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"engine max_seq_len={self.max_seq_len}"
            )
        # feasibility is always judged against the WORST case, even in
        # recompute mode: a request must be able to run to completion
        # alone in an empty pool, or preemption could never unblock it
        need = self.blocks_needed(req)
        pool = self.allocator.num_blocks - 1  # block 0 is reserved
        if need > pool:
            # reject now: admit() could never satisfy it and the engine
            # loop would spin forever on a permanently-waiting head
            raise ValueError(
                f"request {req.rid}: needs {need} KV blocks but the pool "
                f"only has {pool}; raise num_blocks or shrink the request"
            )
        self.waiting.append(req)

    def blocks_needed(self, req: Request) -> int:
        """Whole-lifetime reservation: padded prompt blocks plus room
        for every decoded token's KV (the last sampled token is never
        written back, hence the -1).

        Burst math under spec_k: the deepest verify starts at committed
        length prompt + max_new - 2 (one more commit would finish the
        request) and writes k+1 positions, so the top written position
        is prompt + max_new - 2 + spec_k — reserve
        prompt + max_new - 1 + spec_k positions.  A max_new == 1
        request finishes at prefill and never verifies, so it carries
        no burst headroom.

        Note the three candidates are alternatives under ONE max, not a
        sum: the prompt's block padding and the decode/burst tail
        overlap (decode overwrites pad slots), so adding them would
        double-count the pad.  `test_admission_exact_fit_during_chunked_prefill`
        pins the exact-fit case, including while another request is
        mid-chunk-prefill (whose own in-flight chunk tail padding lives
        inside its already-owned blocks and must not be charged again).
        """
        bs = self.allocator.block_size
        prompt_pad = padded_prompt_len(req.prompt_len, bs)
        total_positions = max(prompt_pad, req.prompt_len + req.max_new_tokens - 1)
        if self.spec_k and req.max_new_tokens > 1:
            total_positions = max(
                total_positions,
                req.prompt_len + req.max_new_tokens - 1 + self.spec_k,
            )
        return self.allocator.blocks_for(total_positions)

    def blocks_initial(self, req: Request) -> int:
        """Blocks to allocate at admission time.  Whole lifetime under
        "off"; under "recompute" just the (block-padded) prefill
        context — decode capacity arrives later via `grow`."""
        if self.preemption == "off":
            return self.blocks_needed(req)
        bs = self.allocator.block_size
        return self.allocator.blocks_for(padded_prompt_len(req.prefill_len, bs))

    # -- deservingness / victim policy -------------------------------------

    @staticmethod
    def deserving(req: Request) -> Tuple[int, int, int]:
        """Total order on requests; larger = more deserving (kept when
        others are preempted).  Lowest priority loses first, then the
        latest arrival, then the highest rid — rid makes the order
        total, which is what guarantees global progress (the maximum is
        never preempted, so it always finishes)."""
        return (req.priority, -req.arrival_step, -req.rid)

    def _pick_victim(self, beneficiary: Request) -> Optional[Request]:
        """Least deserving running request strictly below the
        beneficiary, or None.  Strictness matters: preempting a peer or
        a better request to feed a worse one would thrash forever."""
        bkey = self.deserving(beneficiary)
        victims = [r for r in self.running.values() if self.deserving(r) < bkey]
        return min(victims, key=self.deserving, default=None)

    def _freeable_below(self, beneficiary: Request) -> int:
        """Blocks that would become allocatable (freed or parked on the
        evictable cache LRU) by preempting every running request
        strictly less deserving than ``beneficiary``.  Shared blocks
        (refcount > 1) are conservatively excluded: releasing one
        victim's reference leaves them referenced."""
        bkey = self.deserving(beneficiary)
        return sum(
            sum(1 for b in r.alloc.blocks if self.allocator.refcount(b) <= 1)
            for r in self.running.values()
            if self.deserving(r) < bkey
        )

    # -- per-step scheduling ----------------------------------------------

    def admit(
        self, step: int, on_preempt: Optional[PreemptCallback] = None
    ) -> List[Request]:
        """Admit pending requests while a slot and blocks are free.

        "off": strict FCFS over the waiting queue — stop at the first
        request that does not fit, so a small late request cannot
        starve a big earlier one.

        "recompute": one pass over waiting + preempted requests in
        deserving order.  A candidate that does not fit may preempt
        strictly-less-deserving running victims (checked feasible
        first, so no victim dies for a candidate that still would not
        fit); the pass stops after any admission that needed a
        preemption (evictions settle for one step before anyone less
        deserving is considered), or at the first candidate that cannot
        be satisfied at all — strictness again, so the head of the
        deserving order is never starved by smaller requests behind it.
        """
        if self.preemption == "off":
            return self._admit_fcfs(step)
        return self._admit_preemptive(step, on_preempt)

    def _admit_fcfs(self, step: int) -> List[Request]:
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if req.arrival_step > step:
                break  # queue is arrival-ordered
            need = self.blocks_needed(req)
            if not self.allocator.can_allocate(need):
                break
            self.waiting.popleft()
            self._activate(req, need, step)
            admitted.append(req)
        return admitted

    def _admit_preemptive(
        self, step: int, on_preempt: Optional[PreemptCallback]
    ) -> List[Request]:
        admitted = []
        candidates = sorted(
            [r for r in self.preempted if r.arrival_step <= step]
            + [r for r in self.waiting if r.arrival_step <= step],
            key=self.deserving,
            reverse=True,
        )
        for req in candidates:
            need = self.blocks_initial(req)
            need_slot = not self._free_slots
            short = need - self.allocator.num_available
            if not need_slot and short <= 0:
                self._dequeue_pending(req)
                self._activate(req, need, step)
                admitted.append(req)
                continue
            # feasibility before any eviction: every strictly-less-
            # deserving victim freed must cover both the slot and the
            # block shortfall, or no victim dies for nothing
            victims_exist = self._pick_victim(req) is not None
            if (need_slot and not victims_exist) or (
                short > self._freeable_below(req)
            ):
                break  # strict: nobody behind this candidate goes first
            preempted_any = False
            while (not self._free_slots) or not self.allocator.can_allocate(need):
                victim = self._pick_victim(req)
                assert victim is not None, "feasibility check lied"
                self.preempt(victim, step, on_preempt)
                preempted_any = True
            self._dequeue_pending(req)
            self._activate(req, need, step)
            admitted.append(req)
            if preempted_any:
                break  # let evictions settle before admitting anyone else
        return admitted

    def _dequeue_pending(self, req: Request) -> None:
        if req.state is RequestState.PREEMPTED:
            self.preempted.remove(req)
        else:
            self.waiting.remove(req)

    def _activate(self, req: Request, need: int, step: int) -> None:
        """Give ``req`` a slot and ``need`` blocks.  With prefix
        caching on, the leading full blocks of the prefill context are
        served from the content-addressed cache instead of allocated:
        every hit is acquired (refcount++), ``cached_len``/
        ``prefill_pos`` start at the cached boundary, and only the miss
        suffix is allocated fresh.  At least one token is always left
        for the engine to recompute (the first sampled token needs the
        final position's logits); when that cap lands mid-block — a
        fully cached, block-aligned context — the tail hit becomes a
        pinned copy-on-write source and a private block takes its place
        in the table."""
        al = self.allocator
        bs = al.block_size
        toks = req.prefill_tokens
        hits = al.match_prefix(toks)
        cached_len = min(len(hits) * bs, len(toks) - 1)
        n_keep = cached_len // bs
        blocks = list(hits[:n_keep])
        al.acquire(blocks)
        cow_src: Optional[int] = None
        if cached_len > n_keep * bs:
            cand = hits[n_keep]
            # pinning an IDLE hit takes it off the evictable LRU — one
            # block of allocatable capacity the admission check did not
            # charge.  Pin only if the remaining allocation still fits;
            # otherwise forgo the partial-block hit (correctness never
            # depends on COW, it only saves recompute).
            pin_cost = 1 if al.refcount(cand) == 0 else 0
            if al.num_available - pin_cost >= need - n_keep:
                cow_src = cand
                al.acquire([cow_src])  # pinned: eviction may not scrub it
            else:
                cached_len = n_keep * bs
        blocks.extend(al.allocate(need - n_keep))
        if al.prefix_cache:
            n_hit = n_keep + (1 if cow_src is not None else 0)
            al.hits += n_hit
            al.misses += al.blocks_for(len(toks)) - n_hit
            al.tokens_saved += cached_len
            if cow_src is not None:
                al.cow_copies += 1
        req.alloc = SequenceAllocation(blocks, bs)
        req.cached_len = cached_len
        req.cow_src = cow_src
        req.prefill_pos = cached_len
        req.verified_len = cached_len
        req.drafted_len = cached_len
        req.slot = self._free_slots.pop()
        req.state = RequestState.RUNNING
        req.admitted_step = step
        self.running[req.slot] = req

    def _drop_cow_pin(self, req: Request) -> None:
        """Release the copy-on-write source pin if the engine never got
        to copy it (teardown between activation and first prefill)."""
        if req.cow_src is not None:
            self.allocator.release([req.cow_src])
            req.cow_src = None

    def _release_blocks(self, req: Request, start: int, stop: int) -> List[int]:
        """Release every block ``req`` owns and return the subset that
        (a) reached the free list AND (b) holds the dirty position
        range [start, stop) the caller wants scrubbed.  Blocks that
        stay referenced (shared) or parked as idle cache hold valid
        content and are NEVER scrubbed."""
        dirty = req.alloc.blocks_covering(start, stop)
        freed = set(self.allocator.release(req.alloc.blocks))
        self._drop_cow_pin(req)
        return [b for b in dirty if b in freed]

    # -- on-demand growth (recompute mode) ---------------------------------

    def grow(
        self,
        req: Request,
        min_positions: int,
        on_preempt: Optional[PreemptCallback] = None,
        step: int = -1,
    ) -> bool:
        """Ensure ``req`` owns capacity for ``min_positions`` cache
        positions, allocating blocks on demand and preempting strictly
        less deserving victims under pool pressure.  Returns False when
        ``req`` itself had to be preempted instead (insufficient free +
        freeable blocks) — the caller must drop it from this step's
        batch.  Only meaningful under ``preemption="recompute"``."""
        assert self.preemption == "recompute", "grow() needs preemption on"
        assert req.state is RequestState.RUNNING
        need = self.allocator.blocks_for(min_positions) - len(req.alloc.blocks)
        if need <= 0:
            return True
        if need > self.allocator.num_available + self._freeable_below(req):
            # even evicting everyone less deserving would not cover it:
            # park THIS request until more deserving work retires.  The
            # globally most deserving request can never land here (all
            # other owners are below it and its total demand fits the
            # pool by the submit-time guard), so progress is preserved.
            self.preempt(req, step, on_preempt)
            return False
        while not self.allocator.can_allocate(need):
            victim = self._pick_victim(req)
            assert victim is not None, "feasibility check lied"
            self.preempt(victim, step, on_preempt)
        req.alloc.grow(self.allocator.allocate(need))
        return True

    # -- state transitions -------------------------------------------------

    def preempt(
        self,
        req: Request,
        step: int,
        on_preempt: Optional[PreemptCallback] = None,
    ) -> List[int]:
        """Evict a RUNNING request: release every block it owns and
        park it for a later recompute-resume.  Returns the written
        block ids ([0, drafted_len)) that actually reached the free
        list, which the engine's callback must scrub before the
        allocator reuses them.  Without prefix caching that is every
        written block (a preempted sequence's committed K/V is dead:
        the resume recomputes it).  With it, registered blocks instead
        stay valid cache — shared ones keep their other references and
        the victim's own published prefix parks on the LRU, where the
        resume can hit it again; they are scrubbed only if evicted.

        Speculative state needs no special rollback here: `output`
        only ever holds committed tokens (verify commits before the
        step ends), so freezing ``resume_ctx`` from prompt + output IS
        the roll-back to the verified stream; the drafted-but-rejected
        tail dies with the scrub.
        """
        assert req.state is RequestState.RUNNING
        assert self.preemption == "recompute", "preemption is off"
        scrub = self._release_blocks(req, 0, req.drafted_len)
        slot = req.slot
        req.alloc = None
        del self.running[slot]
        self._free_slots.append(slot)
        req.slot = -1
        req.state = RequestState.PREEMPTED
        req.resume_ctx = list(req.prompt) + req.output[:-1]
        req.prefill_pos = 0
        req.verified_len = 0
        req.drafted_len = 0
        req.cached_len = 0
        req.preempt_count += 1
        req.preempted_step = step
        req.preempted_time = self.clock()
        self.preempted.append(req)
        if on_preempt is not None:
            on_preempt(req, slot, scrub)
        return scrub

    def cancel(self, req: Request, step: int) -> List[int]:
        """Cancel a request wherever it lives (deadline expiry or
        client abort), keeping its committed output.  Returns the block
        ids the engine must scrub (non-empty only for RUNNING victims:
        the never-committed [verified_len, drafted_len) range, same as
        retirement)."""
        stale: List[int] = []
        if req.state is RequestState.WAITING:
            self.waiting.remove(req)
        elif req.state is RequestState.PREEMPTED:
            self.preempted.remove(req)
        elif req.state is RequestState.RUNNING:
            stale = self._release_blocks(req, req.verified_len, req.drafted_len)
            req.alloc = None
            del self.running[req.slot]
            self._free_slots.append(req.slot)
            req.slot = -1
        else:  # FINISHED / CANCELLED: nothing to undo
            return stale
        req.state = RequestState.CANCELLED
        req.finished_step = step
        return stale

    def rollback(self, req: Request, committed_len: int) -> None:
        """Roll a sequence's logical length back after a verify step.

        The verify wrote K/V up to req.drafted_len; only
        ``committed_len`` positions were accepted.  The rejected tail's
        blocks stay owned — the next verify re-writes from
        committed_len, so within the sequence stale entries are always
        overwritten before the committed length reaches them — but the
        truncation must be recorded so retirement knows what to scrub.
        """
        assert req.state is RequestState.RUNNING
        assert req.verified_len <= committed_len <= req.drafted_len, (
            req.verified_len,
            committed_len,
            req.drafted_len,
        )
        assert req.drafted_len <= req.alloc.capacity(), (
            req.drafted_len,
            req.alloc.capacity(),
        )
        req.verified_len = committed_len

    def retire(self, req: Request, step: int) -> List[int]:
        """Retire a finished request, returning its blocks to the free
        list.  Returns the block ids still holding stale
        (written-but-never-committed) K/V — draft tails rolled back by
        `rollback`, prefill padding — which the engine must scrub
        before the allocator hands them to another sequence."""
        assert req.state is RequestState.RUNNING
        req.state = RequestState.FINISHED
        req.finished_step = step
        stale = self._release_blocks(req, req.verified_len, req.drafted_len)
        req.alloc = None
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        return stale

    def expired(self, now: float) -> List[Request]:
        """Every live request whose deadline has passed (waiting,
        running or preempted) — the engine cancels these at the top of
        each step."""
        live = list(self.waiting) + self.preempted + list(self.running.values())
        return [
            r
            for r in live
            if r.deadline_s is not None and now - r.submit_time > r.deadline_s
        ]

    def has_work(self) -> bool:
        return bool(self.running) or bool(self.waiting) or bool(self.preempted)
