"""Serving layer: static batcher + continuous-batching paged engine."""
from .engine import (  # noqa: F401
    ContinuousBatchingEngine,
    Engine,
    PagedServeConfig,
    ServeConfig,
    ServeStats,
)
from .kv_cache import (  # noqa: F401
    BlockAllocator,
    OutOfBlocksError,
    SCRATCH_BLOCK,
    SequenceAllocation,
    padded_prompt_len,
)
from .scheduler import Request, RequestState, Scheduler  # noqa: F401
from .spec import (  # noqa: F401
    Drafter,
    DraftModelDrafter,
    NgramDrafter,
    make_drafter,
)
