"""Serving layer: static batcher + continuous-batching paged engine.

The supported public surface is ``__all__`` — the six names an
application needs (engines, options, handles, tracing, metrics); see
``repro.serving.api`` for the redesign story.  The remaining imports
(allocator, scheduler, drafters, legacy configs) stay importable for
tests and power users but are internal: their signatures may change
between PRs without a deprecation cycle.
"""
from .api import (  # noqa: F401
    PAGED_FAMILIES,
    ServeOptions,
    SubmitHandle,
    build_engine,
)
from .engine import (  # noqa: F401
    ContinuousBatchingEngine,
    Engine,
    PagedServeConfig,
    ServeConfig,
    ServeStats,
)
from .kv_cache import (  # noqa: F401
    BlockAllocator,
    OutOfBlocksError,
    SCRATCH_BLOCK,
    SequenceAllocation,
    padded_prompt_len,
)
from .observability import (  # noqa: F401
    MetricsRegistry,
    RequestBreakdown,
    TraceEvent,
    TraceRecorder,
    check_request_events,
    derive_breakdown,
)
from .scheduler import Request, RequestState, Scheduler  # noqa: F401
from .spec import (  # noqa: F401
    Drafter,
    DraftModelDrafter,
    NgramDrafter,
    make_drafter,
)

__all__ = [
    "Engine",
    "ContinuousBatchingEngine",
    "ServeOptions",
    "SubmitHandle",
    "TraceRecorder",
    "MetricsRegistry",
]
