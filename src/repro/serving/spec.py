"""Drafters for speculative decoding.

A drafter proposes ``k`` continuation tokens for a running request; the
engine then scores all ``k + 1`` positions (the last committed token
plus the drafts) in ONE batched verify step
(`repro.models.transformer.paged_score_tokens`) and commits the longest
prefix the target model agrees with, plus the target's own
correction/bonus token.  Under greedy sampling the committed stream is
provably identical to plain one-token-per-step decode — a drafter can
only change HOW FAST tokens come out, never WHICH tokens.

Two built-in drafters:

* :class:`NgramDrafter` — self-speculative prompt/n-gram lookup: match
  the longest recent suffix of the committed context (prompt + output)
  against an earlier occurrence and propose the tokens that followed
  it.  Needs no extra model; shines on repetitive text (code, structured
  output, greedy repetition loops) and degrades gracefully to ~zero
  acceptance on incompressible context.
* :class:`DraftModelDrafter` — a small draft model sharing the target's
  tokenizer (same vocab), built through the model registry and run
  greedily through the static :class:`~repro.serving.engine.Engine` for
  ``k`` tokens per proposal.

``make_drafter`` resolves the ``PagedServeConfig.spec_draft`` string:
``"ngram"`` / ``"ngram:N"`` (max n-gram width N), or ``"model:<arch>"``
for a registry architecture serving as the draft model.
"""
from __future__ import annotations

from typing import List, Protocol, runtime_checkable

from repro.configs.base import ModelConfig

from .scheduler import Request


@runtime_checkable
class Drafter(Protocol):
    """Anything with ``propose(request, k) -> k token ids``.

    Preemption contract: a request may be evicted mid-stream and later
    resumed with its committed context (prompt + output) intact — by
    the time any drafter sees it again, the engine has already rolled
    speculative state back to the verified stream, so a drafter that
    reads only ``req.prompt + req.output`` (both built-ins do) is
    automatically preemption-safe.  A drafter that caches per-request
    device state (e.g. a draft-model KV cache keyed by rid) may expose
    an optional ``on_preempt(req)`` method; the engine calls it when
    ``req`` is evicted so the cached state can be dropped — on resume
    the context must be re-derived from the committed tokens, never
    from pre-preemption bookkeeping.
    """

    def propose(self, req: Request, k: int) -> List[int]:
        """Return EXACTLY k drafted continuation tokens for ``req``
        given its committed context (prompt + output).  Drafts need not
        be good — wrong tokens are rejected by the verify step — but
        the length contract keeps the verify batch shape static."""
        ...  # pragma: no cover


def _pad_drafts(drafts: List[int], k: int, fallback: int) -> List[int]:
    """Right-pad a (possibly short) draft list to exactly k tokens."""
    out = list(drafts[:k])
    while len(out) < k:
        out.append(out[-1] if out else fallback)
    return out


class NgramDrafter:
    """Self-speculative n-gram lookup over the request's own context.

    For n from ``max_n`` down to ``min_n``: take the last n committed
    tokens as the probe, find its most recent earlier occurrence in the
    context, and propose the k tokens that followed that occurrence.
    Falls back to repeating the last token when nothing matches —
    near-free to verify and occasionally right in a repetition loop.
    """

    def __init__(self, max_n: int = 3, min_n: int = 1):
        assert 1 <= min_n <= max_n, (min_n, max_n)
        self.max_n = max_n
        self.min_n = min_n
        # observability: the engine's metrics registry reads these live
        self.proposals = 0
        self.proposed_tokens = 0

    def describe(self) -> str:
        """Label for the metrics registry's drafter info gauge."""
        return f"ngram:{self.max_n}"

    def propose(self, req: Request, k: int) -> List[int]:
        self.proposals += 1
        self.proposed_tokens += k
        ctx = req.prompt + req.output
        fallback = ctx[-1] if ctx else 0
        for n in range(self.max_n, self.min_n - 1, -1):
            if len(ctx) <= n:
                continue
            probe = ctx[len(ctx) - n :]
            # most recent earlier occurrence wins: recent context is the
            # best predictor of what comes next
            for start in range(len(ctx) - n - 1, -1, -1):
                if ctx[start : start + n] == probe:
                    cont = ctx[start + n : start + n + k]
                    if cont:
                        return _pad_drafts(cont, k, fallback)
        return [fallback] * k


class DraftModelDrafter:
    """Draft with a small model sharing the target's tokenizer.

    The draft model is any registry-built family with a prefill/decode
    path; each proposal greedily decodes k tokens through the static
    Engine, conditioned on a power-of-two suffix **window** of the
    committed context (at most ``window`` tokens).  The window is what
    bounds XLA compiles: the raw context grows every verify step, and
    jitting a fresh prefill per length would cost a compile per engine
    step — a suffix drawn from a fixed shape menu {1, 2, 4, ...,
    window} compiles each shape once.  Drafts are a heuristic, so
    trading distant context for bounded compiles is the right side of
    the bargain (wrong drafts only waste verify positions).

    The draft model's weights are its own (``params``/``key``) — only
    the token space is shared, which is why construction enforces vocab
    equality.  Trained draft weights are supplied via ``params`` (the
    string ``"model:<arch>"`` path builds a reduced random-init model —
    a wiring demo, not a speedup).
    """

    def __init__(
        self,
        draft_cfg: ModelConfig,
        target_cfg: ModelConfig,
        params=None,
        key=None,
        window: int = 32,
    ):
        if draft_cfg.vocab != target_cfg.vocab:
            raise ValueError(
                f"draft model vocab {draft_cfg.vocab} != target vocab "
                f"{target_cfg.vocab}; speculative decoding requires a "
                "shared tokenizer"
            )
        assert window >= 1
        from .engine import Engine, ServeConfig  # lazy: engine imports spec

        self.window = window
        self._engine = Engine(draft_cfg, params=params, key=key)
        self._scfg_cls = ServeConfig
        self._arch = draft_cfg.name
        self.proposals = 0
        self.proposed_tokens = 0

    def describe(self) -> str:
        """Label for the metrics registry's drafter info gauge."""
        return f"model:{self._arch}(window={self.window})"

    def propose(self, req: Request, k: int) -> List[int]:
        import numpy as np
        import jax.numpy as jnp

        self.proposals += 1
        self.proposed_tokens += k
        ctx = req.prompt + req.output
        w = 1
        while w * 2 <= min(len(ctx), self.window):
            w *= 2
        tail = ctx[len(ctx) - w :]
        tokens = jnp.asarray(np.asarray(tail, np.int32)[None])
        out = self._engine.generate(
            {"tokens": tokens}, self._scfg_cls(max_new_tokens=k)
        )
        return _pad_drafts(np.asarray(out)[0].tolist(), k, ctx[-1])


def make_drafter(spec: str, target_cfg: ModelConfig, key=None) -> Drafter:
    """Resolve a ``spec_draft`` string to a drafter instance.

    ``"ngram"`` / ``"ngram:N"``: self-speculative lookup (max width N,
    default 3).  ``"model:<arch>"``: the registry architecture ``arch``
    (reduced, f32) as a draft model — it must share the target's vocab.
    """
    if spec == "ngram" or spec.startswith("ngram:"):
        max_n = int(spec.split(":", 1)[1]) if ":" in spec else 3
        return NgramDrafter(max_n=max_n)
    if spec.startswith("model:"):
        import dataclasses

        from repro.configs import ARCHS, get_config

        arch = spec.split(":", 1)[1]
        if arch not in ARCHS:
            raise ValueError(f"unknown draft arch {arch!r}; pick from {sorted(ARCHS)}")
        draft_cfg = get_config(arch).reduced()
        draft_cfg = dataclasses.replace(
            draft_cfg, param_dtype="float32", act_dtype="float32"
        )
        return DraftModelDrafter(draft_cfg, target_cfg, key=key)
    raise ValueError(
        f"unknown drafter spec {spec!r}; use 'ngram', 'ngram:N' or 'model:<arch>'"
    )
