"""Uniform oracle interface over every posit/PLAM implementation.

An :class:`Impl` exposes the five conformance operations —

* ``encode(x, spec)``    : float32 values  -> posit patterns (int32)
* ``decode(bits, spec)`` : posit patterns  -> float32 values
* ``quantize(x, spec)``  : float32 values  -> float32 posit-grid values
* ``exact_mul(pa, pb, spec)`` : exact posit product patterns
* ``plam_mul(pa, pb, spec)``  : PLAM approximate product patterns

— over host numpy arrays, so the differential fuzzer can compare any
two implementations elementwise without caring which runtime each one
lives in.  Four families are wrapped:

* :class:`GoldenImpl`  — the pure-Python golden model (``golden.py``),
  batch-evaluated through a per-pattern field cache so exhaustive
  small-n sweeps stay tractable.
* :class:`JaxImpl`     — the vectorized bit kernels (``posit.py`` /
  ``plam.py``); ``variant="logfix"`` swaps in the Fig. 4 single-word
  datapath for ``plam_mul``.
* :class:`TableImpl`   — the exhaustive-table codec (``table.py``) for
  the codec ops, plus an independent float64 table formulation of both
  multipliers (decode via value table, multiply/approximate in f64,
  encode via threshold search).
* :class:`PallasImpl`  — the Pallas kernels (``kernels/posit_codec.py``),
  in interpret mode everywhere and compiled on TPU.

:class:`FaultyImpl` wraps any of the above and XORs a bit into one
op's output — the meta-testing hook that proves the differential
fuzzer actually catches single-bit faults in any layer.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.numerics import PositSpec, golden

OPS = ("encode", "decode", "quantize", "exact_mul", "plam_mul")
CODEC_OPS = ("encode", "decode", "quantize")
MUL_OPS = ("exact_mul", "plam_mul")


class Impl:
    """Base class: one named implementation of the conformance ops."""

    name = "base"

    def ops(self, spec: PositSpec):
        """The subset of OPS this impl supports for ``spec``."""
        return OPS

    # each method: numpy in, numpy out (int32 patterns / float32 values)
    def encode(self, x, spec: PositSpec):
        raise NotImplementedError

    def decode(self, bits, spec: PositSpec):
        raise NotImplementedError

    def quantize(self, x, spec: PositSpec):
        raise NotImplementedError

    def exact_mul(self, pa, pb, spec: PositSpec):
        raise NotImplementedError

    def plam_mul(self, pa, pb, spec: PositSpec):
        raise NotImplementedError

    def run(self, op: str, inputs, spec: PositSpec):
        return getattr(self, op)(*inputs, spec)


def outputs_equal(a, b):
    """Elementwise output agreement: exact bits, NaN == NaN."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.dtype.kind == "f":
        both_nan = np.isnan(a) & np.isnan(b)
        av = a.astype(np.float32).view(np.uint32)
        bv = b.astype(np.float32).view(np.uint32)
        return (av == bv) | both_nan
    return np.asarray(a, np.int64) == np.asarray(b, np.int64)


# ---------------------------------------------------------------------------
# golden (pure Python, field-cached batch loops)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=16)
def _golden_fields(n: int, es: int):
    """(sign, k, e, f) per pattern, None for zero/NaR — the batch cache."""
    nar = 1 << (n - 1)
    return tuple(
        None if p in (0, nar) else golden.decode_fields_py(p, n, es)
        for p in range(1 << n)
    )


@lru_cache(maxsize=16)
def _golden_values(n: int, es: int):
    return tuple(golden.decode_py(p, n, es) for p in range(1 << n))


class GoldenImpl(Impl):
    name = "golden"

    def ops(self, spec):
        # the float64 golden model is exact for every supported spec
        return OPS

    def encode(self, x, spec):
        n, es = spec.n, spec.es
        return np.array(
            [golden.encode_py(float(v), n, es) for v in np.ravel(x)], np.int32
        ).reshape(np.shape(x))

    def decode(self, bits, spec):
        vals = _golden_values(spec.n, spec.es)
        mask = spec.mask_n
        return np.array(
            [vals[int(b) & mask] for b in np.ravel(bits)], np.float32
        ).reshape(np.shape(bits))

    def quantize(self, x, spec):
        return self.decode(self.encode(x, spec), spec)

    def _mul(self, pa, pb, spec, plam: bool):
        n, es = spec.n, spec.es
        nar = spec.nar
        mask = spec.mask_n
        fields = _golden_fields(n, es)
        enc = golden.encode_py
        out = np.empty(np.shape(pa), np.int32).ravel()
        pa_flat = np.ravel(np.asarray(pa, np.int64) & mask)
        pb_flat = np.ravel(np.asarray(pb, np.int64) & mask)
        for i in range(out.shape[0]):
            a, b = int(pa_flat[i]), int(pb_flat[i])
            if a == nar or b == nar:
                out[i] = nar
                continue
            if a == 0 or b == 0:
                out[i] = 0
                continue
            sa, ka, ea, fa = fields[a]
            sb, kb, eb, fb = fields[b]
            s = sa ^ sb
            scale = (ka + kb) * (1 << es) + (ea + eb)
            if plam:
                f = fa + fb  # eq. (17)
                if f >= 1.0:  # eqs. (19)-(21)
                    f -= 1.0
                    scale += 1
                val = 2.0**scale * (1.0 + f)
            else:
                val = 2.0**scale * (1.0 + fa) * (1.0 + fb)
            out[i] = enc(-val if s else val, n, es)
        return out.reshape(np.shape(pa))

    def exact_mul(self, pa, pb, spec):
        return self._mul(pa, pb, spec, plam=False)

    def plam_mul(self, pa, pb, spec):
        return self._mul(pa, pb, spec, plam=True)


# ---------------------------------------------------------------------------
# JAX bit kernels
# ---------------------------------------------------------------------------


class JaxImpl(Impl):
    """numerics/posit.py + numerics/plam.py (``variant="logfix"`` uses the
    Fig. 4 single-log-word datapath for plam_mul)."""

    def __init__(self, variant: str = "field"):
        assert variant in ("field", "logfix")
        self.variant = variant
        self.name = "jax" if variant == "field" else "jax_logfix"

    def ops(self, spec):
        if self.variant == "logfix":
            return ("plam_mul",)
        if 2 * spec.fbmax + 1 + spec.es > 30:
            return ("encode", "decode", "quantize", "plam_mul")
        return OPS

    def encode(self, x, spec):
        import jax.numpy as jnp
        from repro.numerics import encode

        return np.asarray(encode(jnp.asarray(np.float32(x)), spec)) & spec.mask_n

    def decode(self, bits, spec):
        import jax.numpy as jnp
        from repro.numerics import decode

        return np.asarray(decode(jnp.asarray(np.int32(bits)), spec))

    def quantize(self, x, spec):
        import jax.numpy as jnp
        from repro.numerics import quantize

        return np.asarray(quantize(jnp.asarray(np.float32(x)), spec))

    def exact_mul(self, pa, pb, spec):
        import jax.numpy as jnp
        from repro.numerics import exact_mul

        out = exact_mul(jnp.asarray(np.int32(pa)), jnp.asarray(np.int32(pb)), spec)
        return np.asarray(out) & spec.mask_n

    def plam_mul(self, pa, pb, spec):
        import jax.numpy as jnp
        from repro.numerics import plam_mul, plam_mul_logfix

        fn = plam_mul_logfix if self.variant == "logfix" else plam_mul
        out = fn(jnp.asarray(np.int32(pa)), jnp.asarray(np.int32(pb)), spec)
        return np.asarray(out) & spec.mask_n


# ---------------------------------------------------------------------------
# exhaustive-table codec + float64 table multipliers
# ---------------------------------------------------------------------------


@lru_cache(maxsize=16)
def _table_f64(n: int, es: int):
    vals = np.asarray(golden.all_values(n, es), np.float64)
    mids = np.asarray(golden.thresholds(n, es), np.float64)
    return vals, mids


class TableImpl(Impl):
    """table.py codec; multipliers re-derived from the f64 value tables.

    The multiplier path is an independent formulation: decode both
    operands through the value table, split magnitude into
    (scale, fraction) with ``np.frexp`` (exact in f64), combine per the
    exact product or the PLAM fraction-sum, and encode by binary search
    over the threshold table with ties-to-even-pattern.
    """

    name = "table"

    def ops(self, spec):
        return OPS if spec.n <= 16 else ()

    def encode(self, x, spec):
        import jax.numpy as jnp
        from repro.numerics import encode_table

        return np.asarray(encode_table(jnp.asarray(np.float32(x)), spec)) & spec.mask_n

    def decode(self, bits, spec):
        import jax.numpy as jnp
        from repro.numerics import decode_table

        return np.asarray(decode_table(jnp.asarray(np.int32(bits)), spec))

    def quantize(self, x, spec):
        return self.decode(self.encode(x, spec), spec)

    def _decode_f64(self, p, spec):
        vals, _ = _table_f64(spec.n, spec.es)
        mask, nar = spec.mask_n, spec.nar
        p = np.asarray(p, np.int64) & mask
        sign = (p >> (spec.n - 1)) & 1
        mag = np.where(sign == 1, (-p) & mask, p)
        body = mag & spec.maxpos_body
        v = vals[np.clip(body - 1, 0, vals.shape[0] - 1)]
        v = np.where(sign == 1, -v, v)
        v = np.where(p == 0, 0.0, v)
        return v, p == nar

    def _encode_f64(self, a, sign, spec):
        """|value| f64 + sign -> pattern, threshold search w/ pattern-RNE."""
        _, mids = _table_f64(spec.n, spec.es)
        j = np.searchsorted(mids, a, side="left")
        jc = np.clip(j, 0, mids.shape[0] - 1)
        tie = (j < mids.shape[0]) & (a == mids[jc])
        body = j + 1
        body = np.where(tie & (body % 2 == 1), body + 1, body)
        body = np.clip(body, 1, spec.maxpos_body)
        pat = np.where(sign, (-body) & spec.mask_n, body)
        return pat.astype(np.int64)

    def _mul(self, pa, pb, spec, plam: bool):
        va, na = self._decode_f64(pa, spec)
        vb, nb = self._decode_f64(pb, spec)
        sign = (va < 0) ^ (vb < 0)
        aa, ab = np.abs(va), np.abs(vb)
        if plam:
            # |x| = m * 2^e with m in [0.5, 1): fraction f = 2m - 1
            ma, ea = np.frexp(np.where(aa == 0, 1.0, aa))
            mb, eb = np.frexp(np.where(ab == 0, 1.0, ab))
            fs = (2.0 * ma - 1.0) + (2.0 * mb - 1.0)
            carry = (fs >= 1.0).astype(np.int64)
            scale = (ea - 1) + (eb - 1) + carry
            mag = np.ldexp(1.0 + fs - carry, scale)
        else:
            mag = aa * ab  # exact in f64 for n <= 16
        out = self._encode_f64(mag, sign, spec)
        out = np.where((aa == 0) | (ab == 0), 0, out)
        out = np.where(na | nb, spec.nar, out)
        return out.astype(np.int32)

    def exact_mul(self, pa, pb, spec):
        return self._mul(pa, pb, spec, plam=False)

    def plam_mul(self, pa, pb, spec):
        return self._mul(pa, pb, spec, plam=True)


# ---------------------------------------------------------------------------
# Pallas kernels (interpret everywhere, compiled on TPU)
# ---------------------------------------------------------------------------


class PallasImpl(Impl):
    """kernels/posit_codec.py staged over VMEM tiles.

    ``interpret=True`` runs the kernel bodies as host jnp (the CPU
    conformance mode); ``interpret=False`` lowers through Mosaic and is
    only registered when a TPU backend is present.
    """

    def __init__(self, interpret: bool = True, block=(64, 256)):
        self.interpret = interpret
        self.block = block
        self.name = "pallas_interp" if interpret else "pallas"

    def ops(self, spec):
        if 2 * spec.fbmax + 1 + spec.es > 30:
            return ("encode", "decode", "quantize", "plam_mul")
        return OPS

    def _kw(self):
        return dict(block=self.block, interpret=self.interpret)

    def encode(self, x, spec):
        from repro.kernels import posit_codec as pc

        out = pc.posit_encode(np.float32(np.atleast_1d(x)), spec, **self._kw())
        return (np.asarray(out) & spec.mask_n).reshape(np.shape(x))

    def decode(self, bits, spec):
        from repro.kernels import posit_codec as pc

        out = pc.posit_decode(np.int32(np.atleast_1d(bits)), spec, **self._kw())
        return np.asarray(out).reshape(np.shape(bits))

    def quantize(self, x, spec):
        from repro.kernels import posit_codec as pc

        out = pc.posit_quantize(np.float32(np.atleast_1d(x)), spec, **self._kw())
        return np.asarray(out).reshape(np.shape(x))

    def exact_mul(self, pa, pb, spec):
        from repro.kernels import posit_codec as pc

        pa1, pb1 = np.int32(np.atleast_1d(pa)), np.int32(np.atleast_1d(pb))
        out = pc.exact_mul_elementwise(pa1, pb1, spec, **self._kw())
        return (np.asarray(out) & spec.mask_n).reshape(np.shape(pa))

    def plam_mul(self, pa, pb, spec):
        from repro.kernels import posit_codec as pc

        pa1, pb1 = np.int32(np.atleast_1d(pa)), np.int32(np.atleast_1d(pb))
        out = pc.plam_mul_elementwise(pa1, pb1, spec, **self._kw())
        return (np.asarray(out) & spec.mask_n).reshape(np.shape(pa))


# ---------------------------------------------------------------------------
# fault injection (meta-testing)
# ---------------------------------------------------------------------------


class FaultyImpl(Impl):
    """XOR ``1 << bit`` into ``op``'s output wherever ``trigger`` fires.

    ``trigger(*inputs)`` returns a boolean mask (or scalar) selecting
    the lanes to corrupt; the default corrupts every lane.  Used by the
    conformance tests to prove a single-bit fault in any one
    implementation is caught and shrunk by the fuzzer.
    """

    def __init__(self, base: Impl, op: str, bit: int = 0, trigger=None):
        assert op in OPS, op
        self.base = base
        self.op = op
        self.bit = bit
        self.trigger = trigger
        self.name = f"{base.name}!{op}^{bit}"

    def ops(self, spec):
        return self.base.ops(spec)

    def _corrupt(self, out, inputs):
        mask = (
            np.ones(np.shape(out), bool)
            if self.trigger is None
            else np.broadcast_to(self.trigger(*inputs), np.shape(out))
        )
        out = np.asarray(out)
        if out.dtype.kind == "f":
            bits = out.astype(np.float32).view(np.uint32)
            bits = np.where(mask, bits ^ np.uint32(1 << self.bit), bits)
            return bits.view(np.float32)
        return np.where(mask, out ^ (1 << self.bit), out)

    def run(self, op, inputs, spec):
        out = self.base.run(op, inputs, spec)
        if op == self.op:
            out = self._corrupt(out, inputs)
        return out

    def __getattr__(self, item):
        if item in OPS:

            def call(*args):
                return self.run(item, args[:-1], args[-1])

            return call
        raise AttributeError(item)


def default_impls(spec: PositSpec, include_compiled: str = "auto"):
    """The oracle matrix for ``spec``: name -> Impl.

    ``include_compiled`` controls the non-interpret Pallas oracle:
    ``"auto"`` registers it only when a TPU backend is available (CPU
    jaxlibs cannot compile Pallas kernels), ``True``/``False`` force.
    """
    impls = {
        "golden": GoldenImpl(),
        "jax": JaxImpl(),
        "jax_logfix": JaxImpl(variant="logfix"),
        "table": TableImpl(),
        "pallas_interp": PallasImpl(interpret=True),
    }
    if include_compiled == "auto":
        import jax

        include_compiled = jax.default_backend() == "tpu"
    if include_compiled:
        impls["pallas"] = PallasImpl(interpret=False)
    return impls
