"""Seeded structured fuzzers + N-way differential / metamorphic checks.

Three operand distributions (paper-motivated; Fixed-Posit and Deep
Positron both validate format corner cases exhaustively):

* ``uniform``  — uniform n-bit patterns: every field combination,
  including the regime-dominated tails.
* ``boundary`` — biased toward the format's corner cases: 0, NaR, ±1,
  ±minpos, ±maxpos, every regime-transition pattern (single-run
  bodies), and ±1-pattern neighbors of all of these.
* ``dnn``      — N(0, 1)-valued operands encoded into the spec, the
  weight/activation regime the paper's Table II accuracy claims live
  in (fractions dense, scales small).

The differential runner evaluates every oracle in the matrix on the
same batch and compares each against the reference (golden) with
bit-exact equality; metamorphic checks assert the algebra that must
hold regardless of implementation — commutativity, sign/negation
symmetry, NaR absorption, multiplicative identity, the eq. (24) error
bound everywhere, and scale-independence of ``plam_relative_error``.

Every mismatch is shrunk to a minimal reproducer (see ``shrink.py``)
before it is reported.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.numerics import PositSpec

from . import shrink as _shrink
from .oracles import CODEC_OPS, MUL_OPS, Impl, default_impls, outputs_equal

MODES = ("uniform", "boundary", "dnn")

DEFAULT_SPECS = (
    PositSpec(6, 0),
    PositSpec(8, 0),
    PositSpec(8, 1),
    PositSpec(10, 1),
    PositSpec(16, 1),
    PositSpec(16, 2),
)


def prop_mult() -> int:
    """CI stress lanes scale fuzz budgets via REPRO_PROP_MULT."""
    return max(1, int(os.environ.get("REPRO_PROP_MULT", "1")))


@dataclasses.dataclass
class Mismatch:
    """One differential disagreement, shrunk to a single operand pair."""

    op: str
    spec: PositSpec
    impl_a: str  # reference
    impl_b: str
    inputs: tuple  # ints for mul/decode ops, floats for encode/quantize
    out_a: object
    out_b: object
    count: int  # lanes that disagreed in the originating batch
    report: str = ""  # shrunk human-readable reproducer


@dataclasses.dataclass
class FuzzReport:
    checked: int = 0  # (impl, op, lane) comparisons performed
    mismatches: List[Mismatch] = dataclasses.field(default_factory=list)
    property_failures: List[str] = dataclasses.field(default_factory=list)
    # one shrunk exemplar per (op, spec, impl pair) across the whole run
    seen: set = dataclasses.field(default_factory=set)

    @property
    def ok(self) -> bool:
        return not self.mismatches and not self.property_failures

    def summary(self) -> str:
        lines = [
            f"conformance fuzz: {self.checked} comparisons, "
            f"{len(self.mismatches)} mismatches, "
            f"{len(self.property_failures)} property failures"
        ]
        for m in self.mismatches:
            lines.append("")
            lines.append(m.report or
                         f"{m.op} {m.spec}: {m.impl_a} vs {m.impl_b} on {m.inputs}")
        lines.extend(f"PROPERTY: {p}" for p in self.property_failures)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# operand generators
# ---------------------------------------------------------------------------


def boundary_patterns(spec: PositSpec) -> np.ndarray:
    """Deterministic corner-case pattern set for ``spec``.

    0, NaR, ±1, ±minpos, ±maxpos, every single-run (pure-regime) body —
    the regime-transition points where the encoded fraction width
    changes — and the ±1 neighbors of all of the above.
    """
    n = spec.n
    mask = spec.mask_n
    one = 1 << (n - 2)  # body 10...0 decodes to +1.0
    core = {0, spec.nar, 1, spec.maxpos_body, one}
    # pure-regime bodies: 0b0..01, 0b0..011, ... and 0b10..0, 0b110..0 ...
    for r in range(1, n):
        core.add((1 << r) - 1)  # low run of ones
        core.add(((1 << r) - 1) << (n - 1 - r) & (mask >> 1))  # high run
    out = set()
    for p in core:
        for d in (-1, 0, 1):
            out.add((p + d) & mask)
            out.add((-(p + d)) & mask)  # negations
    return np.array(sorted(out), np.int32)


def sample_patterns(
    rng: np.random.Generator, spec: PositSpec, count: int, mode: str = "uniform"
) -> np.ndarray:
    """``count`` posit patterns drawn per the given distribution."""
    if mode == "uniform":
        return rng.integers(0, 1 << spec.n, count).astype(np.int32)
    if mode == "boundary":
        pool = boundary_patterns(spec)
        # half exact corners, half uniform so cross terms are exercised
        picks = pool[rng.integers(0, pool.shape[0], count)]
        uni = rng.integers(0, 1 << spec.n, count).astype(np.int32)
        take = rng.random(count) < 0.5
        return np.where(take, picks, uni).astype(np.int32)
    if mode == "dnn":
        from repro.numerics import encode
        import jax.numpy as jnp

        vals = rng.standard_normal(count).astype(np.float32)
        return np.asarray(encode(jnp.asarray(vals), spec), np.int32) & spec.mask_n
    raise ValueError(f"unknown fuzz mode {mode!r}")


def sample_floats(rng: np.random.Generator, count: int) -> np.ndarray:
    """Codec-op inputs: log-uniform magnitudes + specials."""
    mags = 10.0 ** rng.uniform(-30, 30, count)
    signs = np.where(rng.random(count) < 0.5, -1.0, 1.0)
    x = (mags * signs).astype(np.float32)
    with np.errstate(over="ignore"):
        # 1e-40 is an f32 subnormal, 3.5e38 overflows to +inf — both are
        # exactly the corner cases the codecs must agree on
        specials = np.array(
            [0.0, -0.0, 1.0, -1.0, np.nan, np.inf, -np.inf,
             1e-40, -1e-40, 3.5e38],
            np.float32,
        )
    k = min(specials.shape[0], count)
    x[:k] = specials[:k]
    return x


# ---------------------------------------------------------------------------
# differential comparison
# ---------------------------------------------------------------------------


def _neg(p, spec):
    return (-np.asarray(p, np.int64)) & spec.mask_n


def differential_op(
    impls: Dict[str, Impl],
    op: str,
    inputs: Sequence[np.ndarray],
    spec: PositSpec,
    ref: str = "golden",
    report: Optional[FuzzReport] = None,
    max_mismatches: int = 4,
) -> List[Mismatch]:
    """Run ``op`` through every impl supporting it; compare vs ``ref``.

    Each disagreement batch is reduced to its first few offending lanes
    and (for the pattern-pair ops) shrunk to a minimal single pair with
    a paste-ready reproducer attached.
    """
    todo = {name: im for name, im in impls.items() if op in im.ops(spec)}
    if ref not in todo:
        return []
    out_ref = todo[ref].run(op, inputs, spec)
    found: List[Mismatch] = []
    for name, im in todo.items():
        if name == ref:
            continue
        out = im.run(op, inputs, spec)
        eq = outputs_equal(out_ref, out)
        if report is not None:
            report.checked += int(np.size(eq))
        if bool(np.all(eq)):
            continue
        key = (op, spec.n, spec.es, ref, name)
        if report is not None and key in report.seen:
            continue
        if report is not None:
            report.seen.add(key)
        bad = np.flatnonzero(~np.ravel(eq))
        for idx in bad[:max_mismatches]:
            ins = tuple(np.ravel(x)[idx].item() for x in inputs)
            mm = Mismatch(
                op=op,
                spec=spec,
                impl_a=ref,
                impl_b=name,
                inputs=ins,
                out_a=np.ravel(out_ref)[idx].item(),
                out_b=np.ravel(out)[idx].item(),
                count=int(bad.shape[0]),
            )
            _shrink.attach_report(mm, todo[ref], im)
            found.append(mm)
            break  # one shrunk exemplar per impl pair is enough
    if report is not None:
        report.mismatches.extend(found)
    return found


# ---------------------------------------------------------------------------
# metamorphic properties
# ---------------------------------------------------------------------------


def check_metamorphic(
    impl: Impl,
    spec: PositSpec,
    pa: np.ndarray,
    pb: np.ndarray,
    failures: List[str],
) -> None:
    """Algebraic invariants every multiplier implementation must hold."""
    name = impl.name
    ops = impl.ops(spec)
    mask = spec.mask_n
    one = 1 << (spec.n - 2)
    for op in MUL_OPS:
        if op not in ops:
            continue
        ab = np.asarray(impl.run(op, (pa, pb), spec), np.int64) & mask
        ba = np.asarray(impl.run(op, (pb, pa), spec), np.int64) & mask
        if not np.array_equal(ab, ba):
            i = int(np.flatnonzero(ab != ba)[0])
            failures.append(
                f"{name}.{op} {spec}: not commutative at "
                f"pa={int(pa[i]):#x} pb={int(pb[i]):#x}"
            )
        # sign symmetry: (-a) * b == -(a * b); posit negation is exact
        nab = np.asarray(impl.run(op, (_neg(pa, spec), pb), spec), np.int64) & mask
        want = _neg(ab, spec)
        # NaR is its own negation; zero too — covered by _neg
        if not np.array_equal(nab, want):
            i = int(np.flatnonzero(nab != want)[0])
            failures.append(
                f"{name}.{op} {spec}: negation asymmetry at "
                f"pa={int(pa[i]):#x} pb={int(pb[i]):#x}"
            )
        # NaR absorption and multiplicative identity
        nar = np.full_like(pa, spec.nar)
        if not np.all((np.asarray(impl.run(op, (nar, pb), spec), np.int64) & mask)
                      == spec.nar):
            failures.append(f"{name}.{op} {spec}: NaR not absorbing")
        ones = np.full_like(pa, one)
        ida = np.asarray(impl.run(op, (pa, ones), spec), np.int64) & mask
        if not np.array_equal(ida, np.asarray(pa, np.int64) & mask):
            i = int(np.flatnonzero(ida != (np.asarray(pa, np.int64) & mask))[0])
            failures.append(
                f"{name}.{op} {spec}: x*1 != x at pa={int(pa[i]):#x}"
            )


def check_error_model(spec: PositSpec, pa, pb, failures: List[str]) -> None:
    """eq. (24): bound and pure-fraction dependence of the PLAM error."""
    import jax.numpy as jnp

    from repro.numerics import decode_fields, encode_fields, plam_relative_error

    ja, jb = jnp.asarray(np.int32(pa)), jnp.asarray(np.int32(pb))
    err = np.asarray(plam_relative_error(ja, jb, spec), np.float64)
    if err.max() > 1.0 / 9.0 + 1e-6 or err.min() < 0.0:
        failures.append(
            f"plam_relative_error {spec}: out of [0, 1/9] "
            f"(min {err.min():.3g}, max {err.max():.3g})"
        )
    # scale-independence: rebuild each operand pair at shifted scales
    # (fractions preserved); the error must be bit-identical
    sign, scale, frac, is_zero, is_nar = decode_fields(ja, spec)
    sgnb, scaleb, fracb, _, _ = decode_fields(jb, spec)
    ok = ~(np.asarray(is_zero) | np.asarray(is_nar))
    for shift in (-2, 1, 3):
        # keep shifted scales in regime range so the fraction width survives
        lim = spec.max_scale // 2
        sa2 = jnp.clip(scale + shift, -lim, lim)
        pa2 = encode_fields(sign, sa2, frac.astype(jnp.uint32), spec.fbmax, spec)
        err2 = np.asarray(plam_relative_error(pa2, jb, spec), np.float64)
        # only compare lanes whose fraction survived the re-encode
        _, _, frac2, _, _ = decode_fields(pa2, spec)
        same_f = np.asarray(frac2 == frac) & ok & np.asarray(
            jnp.abs(sa2 - scale) == abs(shift)
        )
        if not np.allclose(err[same_f], err2[same_f], rtol=0, atol=0):
            failures.append(
                f"plam_relative_error {spec}: depends on scale (shift {shift})"
            )


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_fuzz(
    specs: Sequence[PositSpec] = DEFAULT_SPECS,
    seed: int = 0,
    count: int = 2048,
    impls: Optional[Dict[str, Impl]] = None,
    modes: Sequence[str] = MODES,
    ref: str = "golden",
    golden_cap: int = 4096,
    log: Callable[[str], None] = lambda s: None,
) -> FuzzReport:
    """Differential + metamorphic fuzz across the oracle matrix.

    ``count`` operands are drawn per (spec, mode); ``REPRO_PROP_MULT``
    multiplies it in CI stress lanes.  The pure-Python golden oracle is
    subsampled to ``golden_cap`` lanes per batch to keep wall-clock
    bounded; the vectorized impls always see the full batch (compared
    against the JAX impl when golden is capped out of a lane).
    """
    count = count * prop_mult()
    report = FuzzReport()
    for spec in specs:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, spec.n, spec.es])
        )
        for mode in modes:
            log(f"fuzz {spec} mode={mode} count={count}")
            pa = sample_patterns(rng, spec, count, mode)
            pb = sample_patterns(rng, spec, count, mode)
            allimpls = impls if impls is not None else default_impls(spec)
            # golden cap: evaluate golden on a prefix slice, the rest of
            # the batch differentials against the jax impl as reference
            cap = min(count, golden_cap)
            capped = {n: i for n, i in allimpls.items()}
            for op in MUL_OPS:
                differential_op(
                    capped, op, (pa[:cap], pb[:cap]), spec, ref=ref, report=report
                )
                if count > cap and "jax" in allimpls and ref == "golden":
                    rest = {n: i for n, i in allimpls.items() if n != "golden"}
                    differential_op(
                        rest, op, (pa[cap:], pb[cap:]), spec, ref="jax",
                        report=report,
                    )
            # codec ops: patterns for decode, floats for encode/quantize
            differential_op(capped, "decode", (pa[:cap],), spec, ref=ref,
                            report=report)
            xs = sample_floats(rng, cap)
            differential_op(capped, "encode", (xs,), spec, ref=ref, report=report)
            differential_op(capped, "quantize", (xs,), spec, ref=ref,
                            report=report)
            # metamorphic algebra on the vectorized impls (full batch) and
            # on golden (capped batch)
            for name, im in allimpls.items():
                batch = cap if name == "golden" else count
                check_metamorphic(im, spec, pa[:batch], pb[:batch],
                                  report.property_failures)
            check_error_model(spec, pa, pb, report.property_failures)
    return report
