"""Mismatch minimization + reproducer reports.

Given a differential disagreement between two implementations on a
batch, reduce it to a *minimal* single operand pair: greedily replace
each operand with structurally simpler patterns (fewer set bits,
shorter bodies, canonical constants) while the two implementations
still disagree.  The final report decodes every posit field of the
minimal operands (via ``golden.decode_fields_py``), shows each
implementation's output, and emits a paste-ready pytest regression
snippet.
"""
from __future__ import annotations

import math
from typing import Callable, Iterable, Tuple

import numpy as np

from repro.numerics import PositSpec, golden

__all__ = ["shrink_pair", "shrink_value", "describe_pattern", "reproducer",
           "attach_report"]


def _popcount(x: int) -> int:
    return bin(x & 0xFFFFFFFF).count("1")


def _cost(p: int) -> Tuple[int, int]:
    """Shrink order: fewer set bits first, then smaller value."""
    return (_popcount(p), p)


def _pattern_candidates(p: int, n: int) -> Iterable[int]:
    """Structurally simpler replacements for pattern ``p`` (maybe equal)."""
    mask = (1 << n) - 1
    one = 1 << (n - 2)
    yield 0
    yield one  # +1.0
    yield 1 << (n - 1)  # NaR
    yield 1  # minpos
    for b in range(n):  # clear each set bit
        if p & (1 << b):
            yield p & ~(1 << b) & mask
    yield (p >> 1) & mask
    yield p & (mask >> 1)  # drop the sign
    yield one | (p & (one - 1))  # same fraction-ish bits at scale ~1


def shrink_value(
    interesting: Callable[[int], bool], p: int, n: int, max_steps: int = 4096
) -> int:
    """Greedy single-pattern shrink: smallest-cost candidate that stays
    interesting, iterated to a fixed point."""
    steps = 0
    while steps < max_steps:
        steps += 1
        best = None
        for c in _pattern_candidates(p, n):
            if c == p or _cost(c) >= _cost(p):
                continue
            if best is not None and _cost(c) >= _cost(best):
                continue
            if interesting(c):
                best = c
        if best is None:
            return p
        p = best
    return p


def shrink_pair(
    interesting: Callable[[int, int], bool],
    pa: int,
    pb: int,
    n: int,
    max_steps: int = 4096,
) -> Tuple[int, int]:
    """Minimize ``(pa, pb)`` while ``interesting(pa, pb)`` holds.

    Alternates single-operand shrinks until neither operand can get
    simpler — the classic delta-debugging fixed point, specialized to
    bit patterns.
    """
    assert interesting(pa, pb), "shrink_pair needs a failing pair to start"
    while True:
        pa2 = shrink_value(lambda a: interesting(a, pb), pa, n, max_steps)
        pb2 = shrink_value(lambda b: interesting(pa2, b), pb, n, max_steps)
        if (pa2, pb2) == (pa, pb):
            return pa, pb
        pa, pb = pa2, pb2


def describe_pattern(p: int, spec: PositSpec) -> str:
    """One-line field decode: sign/regime k/exponent e/fraction f/value."""
    n, es = spec.n, spec.es
    p &= spec.mask_n
    if p == 0:
        return f"{p:#0{n // 4 + 2}x} = zero"
    if p == spec.nar:
        return f"{p:#0{n // 4 + 2}x} = NaR"
    s, k, e, f = golden.decode_fields_py(p, n, es)
    v = golden.decode_py(p, n, es)
    return (
        f"{p:#0{n // 4 + 2}x} = {'-' if s else '+'}2^{k * (1 << es) + e}"
        f"*(1+{f:.6g})  [k={k} e={e} f={f:.6g}]  value {v:.8g}"
    )


def _fmt_out(v) -> str:
    if isinstance(v, float):
        return f"{v!r} (0x{np.float32(v).view(np.uint32).item():08x})" \
            if not math.isnan(v) else "nan"
    return hex(int(v))


def reproducer(mm, spec: PositSpec) -> str:
    """Human-readable report + paste-ready pytest snippet for a mismatch."""
    n, es = spec.n, spec.es
    lines = [
        f"CONFORMANCE MISMATCH  op={mm.op}  spec=Posit<{n},{es}>  "
        f"{mm.impl_a} vs {mm.impl_b}  ({mm.count} lanes in batch)",
    ]
    if mm.op in ("exact_mul", "plam_mul", "decode"):
        for tag, p in zip(("a", "b"), mm.inputs):
            lines.append(f"  operand {tag}: {describe_pattern(int(p), spec)}")
    else:
        lines.append(f"  input x = {mm.inputs[0]!r}")
    lines.append(f"  {mm.impl_a:>14}: {_fmt_out(mm.out_a)}")
    lines.append(f"  {mm.impl_b:>14}: {_fmt_out(mm.out_b)}")
    args = ", ".join(repr(v) for v in mm.inputs)
    test_name = f"test_regression_{mm.op}_p{n}_{es}_{mm.impl_b}".replace(
        "!", "_faulty_").replace("^", "_bit")
    lines += [
        "",
        "  # --- paste-ready regression test " + "-" * 30,
        "  from repro.numerics import PositSpec",
        "  from repro.conformance import default_impls, outputs_equal",
        "",
        f"  def {test_name}():",
        f"      spec = PositSpec({n}, {es})",
        "      impls = default_impls(spec)",
        f"      a = impls[{mm.impl_a!r}].run({mm.op!r}, ({args},), spec)",
        f"      b = impls[{mm.impl_b.split('!')[0]!r}].run({mm.op!r}, ({args},), spec)",
        "      assert outputs_equal(a, b).all()",
    ]
    return "\n".join(lines)


def attach_report(mm, impl_ref, impl_bad) -> None:
    """Shrink a mul-op mismatch to a minimal pair and attach its report.

    Codec-op mismatches keep their single offending input (floats do
    not shrink meaningfully on the posit grid); pattern-pair ops run the
    full delta-debugging loop with single-pair re-evaluations.
    """
    spec = mm.spec
    if mm.op in ("exact_mul", "plam_mul"):

        def interesting(a: int, b: int) -> bool:
            oa = np.ravel(impl_ref.run(mm.op, (np.int32([a]), np.int32([b])), spec))
            ob = np.ravel(impl_bad.run(mm.op, (np.int32([a]), np.int32([b])), spec))
            from .oracles import outputs_equal

            return not bool(outputs_equal(oa, ob).all())

        pa, pb = int(mm.inputs[0]), int(mm.inputs[1])
        pa, pb = shrink_pair(interesting, pa, pb, spec.n)
        oa = np.ravel(impl_ref.run(mm.op, (np.int32([pa]), np.int32([pb])), spec))
        ob = np.ravel(impl_bad.run(mm.op, (np.int32([pa]), np.int32([pb])), spec))
        mm.inputs = (pa, pb)
        mm.out_a = oa[0].item()
        mm.out_b = ob[0].item()
    mm.report = reproducer(mm, spec)
