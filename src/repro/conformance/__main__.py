"""CLI: ``python -m repro.conformance {gen,check,fuzz}``.

* ``gen``   — regenerate the committed vector files under
  ``tests/vectors/`` (cross-checking the whole oracle matrix first).
* ``check`` — verify committed vectors against every implementation;
  exit 1 on drift.  This is the fast-lane CI gate.
* ``fuzz``  — run the seeded differential + metamorphic fuzzer; on
  mismatch, print the shrunk minimal reproducers, write them to
  ``--out`` for CI artifact upload, and exit 1.  ``REPRO_PROP_MULT``
  scales the per-batch example budget (the nightly stress lane runs
  10x across a seed matrix).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro.numerics import PositSpec

from .fuzz import DEFAULT_SPECS, run_fuzz
from .vectors import VECTOR_DIR, check_vectors, generate_vectors


def _parse_specs(text):
    if not text:
        return DEFAULT_SPECS
    out = []
    for item in text.split(","):
        n, es = item.strip().split(":")
        out.append(PositSpec(int(n), int(es)))
    return tuple(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.conformance")
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("gen", help="regenerate committed golden vectors")
    g.add_argument("--dir", default=None, help=f"vector dir (default {VECTOR_DIR})")
    g.add_argument("--seed", type=int, default=0)

    c = sub.add_parser("check", help="verify committed vectors (CI fast gate)")
    c.add_argument("--dir", default=None)

    f = sub.add_parser("fuzz", help="differential + metamorphic fuzz")
    f.add_argument("--seed", type=int, default=0)
    f.add_argument("--count", type=int, default=2048,
                   help="operands per (spec, mode); REPRO_PROP_MULT multiplies")
    f.add_argument("--specs", default=None,
                   help='comma list like "16:1,8:0" (default: the full matrix)')
    f.add_argument("--out", default=None,
                   help="directory for shrunk-reproducer artifacts on failure")

    args = ap.parse_args(argv)

    if args.cmd == "gen":
        paths = generate_vectors(directory=args.dir and pathlib.Path(args.dir),
                                 seed=args.seed, log=print)
        print(f"wrote {len(paths)} vector files")
        return 0

    if args.cmd == "check":
        failures = check_vectors(directory=args.dir and pathlib.Path(args.dir),
                                 log=lambda s: None)
        if failures:
            print("conformance vector check FAILED:")
            for msg in failures:
                print("  " + msg)
            return 1
        print("conformance vectors: all implementations agree")
        return 0

    report = run_fuzz(specs=_parse_specs(args.specs), seed=args.seed,
                      count=args.count, log=print)
    print(report.summary())
    if not report.ok and args.out:
        out = pathlib.Path(args.out)
        out.mkdir(parents=True, exist_ok=True)
        name = f"conformance_seed{args.seed}.txt"
        (out / name).write_text(report.summary() + "\n")
        print(f"wrote {out / name}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
