"""Differential conformance + fuzzing for the posit/PLAM numerics stack.

The repo carries four semi-independent implementations of Posit<n,es>
arithmetic — the pure-Python golden model (``numerics/golden.py``), the
vectorized JAX bit kernels (``numerics/posit.py`` / ``plam.py``), the
exhaustive-table codec (``numerics/table.py``) and the Pallas kernels
(``kernels/posit_codec.py`` / ``plam_matmul.py``).  This package is the
correctness backbone that keeps them mutually bit-exact:

* :mod:`repro.conformance.oracles` — a uniform :class:`Impl` interface
  over every implementation (encode / decode / quantize / exact_mul /
  plam_mul per :class:`~repro.numerics.PositSpec`).
* :mod:`repro.conformance.fuzz` — seeded structured fuzzers (uniform,
  boundary-biased and DNN-like operand distributions) running N-way
  differential comparison plus metamorphic property checks.
* :mod:`repro.conformance.shrink` — mismatch minimization down to a
  single operand pair, with full field decodes and a paste-ready
  regression-test snippet.
* :mod:`repro.conformance.vectors` — committed golden vector files
  under ``tests/vectors/`` (generate / check / regenerate).

CLI: ``python -m repro.conformance {gen,check,fuzz}``.
"""

from .oracles import (  # noqa: F401
    CODEC_OPS,
    MUL_OPS,
    OPS,
    FaultyImpl,
    GoldenImpl,
    Impl,
    JaxImpl,
    PallasImpl,
    TableImpl,
    default_impls,
    outputs_equal,
)
from .fuzz import (  # noqa: F401
    FuzzReport,
    Mismatch,
    boundary_patterns,
    run_fuzz,
    sample_patterns,
)
from .shrink import reproducer, shrink_pair  # noqa: F401
from .vectors import check_vectors, generate_vectors  # noqa: F401
