"""Committed golden conformance vectors under ``tests/vectors/``.

A vector file pins the full input/output relation of one op on one
spec so that a refactor of *any* single layer (golden, JAX, table,
Pallas) diffs against an artifact none of the layers can silently
move:

* ``kind="exhaustive"`` — ALL bit pairs (multipliers) or ALL patterns
  (decode) for n <= 10: the result array is hashed (sha256 over
  little-endian uint16 patterns / uint32 f32 bits), plus a handful of
  explicit sample triples for human debugging and for spot-checking
  the slow pure-Python golden model.
* ``kind="sampled"`` — a seeded pattern sample for n = 16 where
  all-pairs is out of reach; same hash + samples format.

``generate_vectors`` cross-checks the whole oracle matrix (vectorized
impls on the full set, golden on the samples) and refuses to write
vectors the implementations disagree on.  ``check_vectors`` recomputes
every vectorized impl's full-array hash against the committed file and
re-runs golden on the stored samples — drift in any layer fails PRs.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
from typing import Dict, List, Optional

import numpy as np

from repro.numerics import PositSpec

from .oracles import Impl, default_impls, outputs_equal

VECTOR_DIR = pathlib.Path(__file__).resolve().parents[3] / "tests" / "vectors"

EXHAUSTIVE_SPECS = ((6, 0), (8, 0), (8, 1), (10, 1))
SAMPLED_SPECS = ((16, 1),)
SAMPLED_COUNT = 4096
VECTOR_MUL_OPS = ("plam_mul", "exact_mul")
N_SAMPLES = 32
FORMAT_VERSION = 1


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _hash_patterns(out: np.ndarray) -> str:
    return _sha((np.asarray(out, np.int64) & 0xFFFF).astype("<u2"))


def _hash_floats(out: np.ndarray) -> str:
    return _sha(np.asarray(out, np.float32).view(np.uint32).astype("<u4"))


def pair_grid(n: int):
    """All (pa, pb) bit pairs for an n-bit posit, flattened."""
    pats = np.arange(1 << n, dtype=np.int32)
    pa = np.repeat(pats, 1 << n)
    pb = np.tile(pats, 1 << n)
    return pa, pb


def sampled_pairs(n: int, seed: int, count: int):
    rng = np.random.default_rng(np.random.SeedSequence([seed, n, 0xC0]))
    pa = rng.integers(0, 1 << n, count).astype(np.int32)
    pb = rng.integers(0, 1 << n, count).astype(np.int32)
    return pa, pb


def _vector_inputs(op: str, spec: PositSpec, kind: str, seed: int):
    if op in VECTOR_MUL_OPS:
        if kind == "exhaustive":
            return pair_grid(spec.n)
        return sampled_pairs(spec.n, seed, SAMPLED_COUNT)
    assert op == "decode", op
    if kind == "exhaustive":
        return (np.arange(1 << spec.n, dtype=np.int32),)
    rng = np.random.default_rng(np.random.SeedSequence([seed, spec.n, 0xDE]))
    return (rng.integers(0, 1 << spec.n, SAMPLED_COUNT).astype(np.int32),)


def _file_name(op: str, n: int, es: int, kind: str) -> str:
    return f"{op}_p{n}es{es}_{kind}.json"


def plan() -> List[dict]:
    """Every vector file this repo commits: op x spec x kind."""
    out = []
    for n, es in EXHAUSTIVE_SPECS:
        for op in VECTOR_MUL_OPS + ("decode",):
            out.append(dict(op=op, n=n, es=es, kind="exhaustive"))
    for n, es in SAMPLED_SPECS:
        for op in VECTOR_MUL_OPS + ("decode",):
            out.append(dict(op=op, n=n, es=es, kind="sampled"))
    return out


def _compute(impl: Impl, op: str, inputs, spec: PositSpec) -> np.ndarray:
    return np.asarray(impl.run(op, inputs, spec))


def _sample_indices(total: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, total]))
    k = min(N_SAMPLES, total)
    return np.sort(rng.choice(total, size=k, replace=False))


def generate_vectors(
    directory: Optional[pathlib.Path] = None,
    seed: int = 0,
    impls: Optional[Dict[str, Impl]] = None,
    log=lambda s: None,
) -> List[pathlib.Path]:
    """(Re)generate every vector file, cross-checking the oracle matrix.

    The canonical result array comes from the JAX impl (fast); before
    writing, every other vectorized impl must match it exactly on the
    full set and the golden model must match on the stored samples —
    generation aborts on any disagreement, so a committed vector is
    already an N-way agreement certificate.
    """
    directory = pathlib.Path(directory or VECTOR_DIR)
    directory.mkdir(parents=True, exist_ok=True)
    written = []
    for item in plan():
        op, n, es, kind = item["op"], item["n"], item["es"], item["kind"]
        spec = PositSpec(n, es)
        allimpls = impls if impls is not None else default_impls(spec)
        inputs = _vector_inputs(op, spec, kind, seed)
        log(f"gen {op} Posit<{n},{es}> {kind} ({len(inputs[0])} lanes)")
        ref = _compute(allimpls["jax"], op, inputs, spec)
        for name, im in allimpls.items():
            if name in ("jax", "golden") or op not in im.ops(spec):
                continue
            out = _compute(im, op, inputs, spec)
            bad = ~outputs_equal(ref, out)
            if bad.any():
                i = int(np.flatnonzero(bad)[0])
                raise AssertionError(
                    f"refusing to write {op} Posit<{n},{es}>: jax vs {name} "
                    f"disagree at lane {i} "
                    f"(inputs {[int(np.ravel(x)[i]) for x in inputs]})"
                )
        idx = _sample_indices(len(ref), seed)
        gold_in = tuple(np.ravel(x)[idx] for x in inputs)
        gold_out = _compute(allimpls["golden"], op, gold_in, spec)
        if (~outputs_equal(ref[idx], gold_out)).any():
            raise AssertionError(
                f"refusing to write {op} Posit<{n},{es}>: golden disagrees "
                f"on sampled lanes"
            )
        if op == "decode":
            digest = _hash_floats(ref)
            samples = [
                [int(gold_in[0][j]),
                 int(np.float32(gold_out[j]).view(np.uint32))]
                for j in range(len(idx))
            ]
        else:
            digest = _hash_patterns(ref)
            samples = [
                [int(gold_in[0][j]), int(gold_in[1][j]), int(gold_out[j])]
                for j in range(len(idx))
            ]
        doc = dict(
            version=FORMAT_VERSION,
            op=op,
            spec=[n, es],
            kind=kind,
            seed=seed,
            count=int(len(ref)),
            sha256=digest,
            samples=samples,
        )
        path = directory / _file_name(op, n, es, kind)
        path.write_text(json.dumps(doc, indent=1) + "\n")
        written.append(path)
    return written


def check_vectors(
    directory: Optional[pathlib.Path] = None,
    impls: Optional[Dict[str, Impl]] = None,
    log=lambda s: None,
) -> List[str]:
    """Verify every committed vector file; returns failure strings.

    Vectorized impls recompute the full result array and must hash to
    the committed digest; the pure-Python golden model re-evaluates the
    stored sample triples (full golden evaluation is the job of the
    exhaustive sweep tests, not this fast gate).
    """
    directory = pathlib.Path(directory or VECTOR_DIR)
    failures: List[str] = []
    files = sorted(directory.glob("*.json"))
    if not files:
        return [f"no vector files under {directory} (run `python -m "
                f"repro.conformance gen`)"]
    names = {_file_name(i["op"], i["n"], i["es"], i["kind"]) for i in plan()}
    missing = names - {f.name for f in files}
    failures.extend(f"missing vector file {m}" for m in sorted(missing))
    for path in files:
        doc = json.loads(path.read_text())
        op = doc["op"]
        n, es = doc["spec"]
        spec = PositSpec(n, es)
        allimpls = impls if impls is not None else default_impls(spec)
        inputs = _vector_inputs(op, spec, doc["kind"], doc["seed"])
        if len(inputs[0]) != doc["count"]:
            failures.append(f"{path.name}: input-set size drifted")
            continue
        hasher = _hash_floats if op == "decode" else _hash_patterns
        for name, im in allimpls.items():
            if name == "golden" or op not in im.ops(spec):
                continue
            log(f"check {path.name} vs {name}")
            digest = hasher(_compute(im, op, inputs, spec))
            if digest != doc["sha256"]:
                failures.append(
                    f"{path.name}: {name} hash {digest[:16]}… != committed "
                    f"{doc['sha256'][:16]}…"
                )
        golden = allimpls["golden"]
        for s in doc["samples"]:
            if op == "decode":
                pat, want_bits = s
                got = np.float32(golden.decode(np.int32([pat]), spec)[0])
                if int(got.view(np.uint32)) != want_bits and not (
                    np.isnan(got)
                    and np.isnan(np.uint32(want_bits).view(np.float32))
                ):
                    failures.append(
                        f"{path.name}: golden decode({pat:#x}) = {got!r}, "
                        f"vector says bits {want_bits:#010x}"
                    )
            else:
                pa, pb, want = s
                got = int(
                    np.ravel(golden.run(op, (np.int32([pa]), np.int32([pb])),
                                        spec))[0]
                )
                if got != want:
                    failures.append(
                        f"{path.name}: golden {op}({pa:#x}, {pb:#x}) = "
                        f"{got:#x}, vector says {want:#x}"
                    )
    return failures
