"""Vectorized Posit<n,es> codec in pure JAX (int32/uint32 bit kernels).

Patterns are carried in ``int32`` arrays (one posit per lane; the unused
high bits of patterns with n < 32 are zero).  All field arithmetic uses
``uint32`` internally so shifts are logical.

Bit-exactness guarantees (validated in tests against ``golden.py``):

* ``decode``/``encode`` are bit-exact for every supported spec with
  n <= 24 (the f32 mantissa holds the full posit fraction).  For
  n in (24, 32] decode-to-f32 performs one extra RNE rounding step.
* ``encode_fields`` implements SoftPosit-style pattern rounding
  (round-to-nearest-even on the underlying bit pattern, saturating at
  +-maxpos, never rounding a non-zero value to zero/NaR).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

I32 = jnp.int32
U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class PositSpec:
    """Static description of a Posit<n,es> format."""

    n: int = 16
    es: int = 1

    def __post_init__(self):
        assert 4 <= self.n <= 32, "posit width must be in [4, 32]"
        assert 0 <= self.es <= 3
        assert self.fbmax >= 1
        # decode-to-f32 requires the scale range to fit the f32 exponent
        assert (self.n - 2) * (1 << self.es) <= 126

    # -- derived static fields -------------------------------------------------
    @property
    def useed_exp(self) -> int:  # log2(useed) = 2^es
        return 1 << self.es

    @property
    def fbmax(self) -> int:
        # sign + minimal 2-bit regime + es exponent bits
        return self.n - 3 - self.es

    @property
    def mask_n(self) -> int:
        return (1 << self.n) - 1 if self.n < 32 else 0xFFFFFFFF

    @property
    def nar(self) -> int:
        return 1 << (self.n - 1)

    @property
    def maxpos_body(self) -> int:
        return (1 << (self.n - 1)) - 1

    @property
    def max_scale(self) -> int:  # scale of maxpos
        return (self.n - 2) * self.useed_exp

    @property
    def storage_dtype(self):
        return jnp.int32


P16 = PositSpec(16, 1)
P8 = PositSpec(8, 0)
P32 = PositSpec(32, 2)


def _clz32(x):
    """Count leading zeros of a uint32 via smear + popcount."""
    x = x.astype(U32)
    x = x | (x >> U32(1))
    x = x | (x >> U32(2))
    x = x | (x >> U32(4))
    x = x | (x >> U32(8))
    x = x | (x >> U32(16))
    return (U32(32) - jax.lax.population_count(x)).astype(I32)


def _shl(x, s):
    """Safe variable left shift: result 0 when s >= 32 or s < 0."""
    s = s.astype(I32) if hasattr(s, "astype") else jnp.asarray(s, I32)
    ok = (s >= 0) & (s < 32)
    sc = jnp.clip(s, 0, 31).astype(U32)
    return jnp.where(ok, x.astype(U32) << sc, U32(0))


def _shr(x, s):
    """Safe variable logical right shift: 0 when s >= 32, identity floor 0."""
    s = s.astype(I32) if hasattr(s, "astype") else jnp.asarray(s, I32)
    ok = (s >= 0) & (s < 32)
    sc = jnp.clip(s, 0, 31).astype(U32)
    return jnp.where(ok, x.astype(U32) >> sc, U32(0))


@partial(jax.jit, static_argnames=("spec",))
def decode_fields(bits, spec: PositSpec):
    """Unpack patterns -> (sign, scale, frac, is_zero, is_nar).

    * ``sign``  : int32, 0 or 1
    * ``scale`` : int32, k * 2^es + e   (eq. (1) exponent of 2)
    * ``frac``  : int32 in [0, 2^fbmax), fraction left-aligned to
      ``spec.fbmax`` fractional bits, so value = (-1)^s 2^scale (1 + frac/2^fbmax)
    """
    n, es, fb = spec.n, spec.es, spec.fbmax
    u = bits.astype(U32) & U32(spec.mask_n)
    is_zero = u == U32(0)
    is_nar = u == U32(spec.nar)
    sign = (u >> U32(n - 1)).astype(I32) & I32(1)
    mag = jnp.where(sign == 1, (U32(0) - u) & U32(spec.mask_n), u)
    body = mag & U32(spec.maxpos_body)
    # Left-align the n-1 body bits so the first regime bit is bit 31.
    v = body << U32(33 - n)
    r0 = (v >> U32(31)).astype(I32)
    pad = U32((1 << (33 - n)) - 1)
    w = jnp.where(r0 == 1, ~v, v) | pad
    m = _clz32(w)  # regime run length, in [1, n-1]
    k = jnp.where(r0 == 1, m - 1, -m)
    rest = _shl(v, m + 1)  # exponent+fraction bits, left-aligned at bit 31
    if es > 0:
        e = (rest >> U32(32 - es)).astype(I32)
    else:
        e = jnp.zeros_like(k)
    frac = ((rest << U32(es)) >> U32(32 - fb)).astype(I32)
    scale = k * I32(1 << es) + e
    return sign, scale, frac, is_zero, is_nar


@partial(jax.jit, static_argnames=("spec", "fbits_static"))
def encode_fields(sign, scale, frac, fbits, spec: PositSpec, fbits_static=None):
    """Pack (sign, scale, fraction) -> posit pattern with RNE rounding.

    ``frac`` holds ``fbits`` fractional bits (value = frac / 2^fbits in
    [0, 1)).  ``fbits`` may be a per-element int32 array (needed by the
    exact multiplier, where fraction normalization shifts the width) or
    a Python int.  Requires es + max(fbits) <= 30 so the combined
    exponent|fraction word fits uint32 with headroom.

    Implements pattern-space round-to-nearest-even (== SoftPosit):
    assemble regime|exp|frac at full precision, then RNE the dropped
    low bits; the carry correctly rolls fraction -> exponent -> regime.
    Saturates at maxpos / minpos.
    """
    n, es = spec.n, spec.es
    del fbits_static
    scale = scale.astype(I32)
    fbits = jnp.asarray(fbits, I32)
    frac = frac.astype(U32)

    if es > 0:
        k = scale >> I32(es)  # arithmetic shift == floor division
        e = (scale & I32((1 << es) - 1)).astype(U32)
    else:
        k = scale
        e = jnp.zeros_like(scale, dtype=U32)

    too_big = k >= I32(n - 2)
    too_small = k <= I32(-(n - 1))
    kc = jnp.clip(k, -(n - 2), n - 3)
    m = jnp.where(kc >= 0, kc + 2, 1 - kc)  # regime field width incl. terminator
    avail = I32(n - 1) - m  # bits left for exponent+fraction
    regime = jnp.where(kc >= 0, _shl(jnp.ones_like(kc, U32), kc + 2) - U32(2), U32(1))

    combined = _shl(e, fbits) | frac  # es + fbits significant bits
    tot = I32(es) + fbits
    shift_out = tot - avail

    kept = jnp.where(
        shift_out > 0, _shr(combined, shift_out), _shl(combined, -shift_out)
    )
    round_bit = jnp.where(
        shift_out > 0, _shr(combined, shift_out - 1) & U32(1), U32(0)
    )
    sticky_mask = jnp.where(
        shift_out > 1, _shl(jnp.ones_like(combined), shift_out - 1) - U32(1), U32(0)
    )
    sticky = (combined & sticky_mask) != U32(0)
    # ties-to-even on the FULL pattern (regime included): SoftPosit's
    # `ui += bitNPlusOne & (bitsMore | (ui & 1))`
    body_pre = _shl(regime, avail) + kept
    inc = round_bit & (sticky | ((body_pre & U32(1)) == U32(1))).astype(U32)
    body = body_pre + inc
    body = jnp.minimum(body, U32(spec.maxpos_body))  # carry past maxpos saturates
    body = jnp.where(too_big, U32(spec.maxpos_body), body)
    body = jnp.where(too_small, U32(1), body)  # minpos: never round to zero

    pattern = jnp.where(sign.astype(I32) == 1, (U32(0) - body) & U32(spec.mask_n), body)
    return pattern.astype(I32)


@partial(jax.jit, static_argnames=("spec",))
def decode(bits, spec: PositSpec):
    """Posit patterns -> float32 values (bit-exact for n <= 24)."""
    fb = spec.fbmax
    sign, scale, frac, is_zero, is_nar = decode_fields(bits, spec)
    if fb <= 23:
        mant = frac.astype(U32) << U32(23 - fb)
    else:  # one extra RNE step into the f32 mantissa
        sh = fb - 23
        mant = frac.astype(U32)
        lower = mant & U32((1 << sh) - 1)
        half = U32(1 << (sh - 1))
        mant_hi = mant >> U32(sh)
        rnd = (lower > half) | ((lower == half) & ((mant_hi & U32(1)) == U32(1)))
        mant = mant_hi + rnd.astype(U32)
        # mantissa carry into the exponent
        ovf = mant >> U32(23)
        scale = scale + ovf.astype(I32)
        mant = mant & U32(0x7FFFFF)
    fbits32 = (
        sign.astype(U32) << U32(31)
        | ((scale + I32(127)).astype(U32) << U32(23))
        | mant
    )
    val = jax.lax.bitcast_convert_type(fbits32, jnp.float32)
    val = jnp.where(is_zero, jnp.float32(0), val)
    val = jnp.where(is_nar, jnp.float32(jnp.nan), val)
    return val


@partial(jax.jit, static_argnames=("spec",))
def encode(x, spec: PositSpec):
    """float32 values -> posit patterns (RNE, saturating)."""
    x32 = x.astype(jnp.float32)
    b = jax.lax.bitcast_convert_type(x32, U32)
    sign = (b >> U32(31)).astype(I32)
    raw_e = ((b >> U32(23)) & U32(0xFF)).astype(I32)
    mant = b & U32(0x7FFFFF)
    is_zero = (b & U32(0x7FFFFFFF)) == U32(0)
    is_nar = raw_e == I32(255)  # inf/nan -> NaR
    scale = raw_e - I32(127)  # subnormals get scale -127 -> clamps to minpos
    bits = encode_fields(sign, scale, mant, 23, spec)
    bits = jnp.where(is_zero, I32(0), bits)
    bits = jnp.where(is_nar, I32(spec.nar), bits)
    return bits


@partial(jax.custom_jvp, nondiff_argnums=(1,))
def quantize(x, spec: PositSpec):
    """Project x onto the Posit<n,es> grid (straight-through gradient)."""
    return decode(encode(x, spec), spec).astype(x.dtype)


@quantize.defjvp
def _quantize_jvp(spec, primals, tangents):
    (x,), (dx,) = primals, tangents
    return quantize(x, spec), dx  # STE: identity pass-through


def pack16(bits):
    """int32 posit16 patterns -> int16 storage."""
    return bits.astype(jnp.uint16).astype(jnp.int16)


def unpack16(stored):
    return stored.astype(jnp.uint16).astype(jnp.int32)
