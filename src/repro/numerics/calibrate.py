"""Greedy mixed-numerics calibration: how much PLAM can a model take?

Per-layer / per-role sensitivity to approximate multiplication is the
whole point of a per-site policy (Deep Positron, Fixed-Posit) — this
module automates the assignment.  Given a model, an eval batch and an
accuracy budget, :func:`calibrate` walks candidate sites in order of
estimated multiplier-cost savings (widest hardware impact first) and
keeps the PLAM assignment whenever the eval loss stays within budget;
sites that bust the budget fall back to exact posit, then to the base
config.  The result is a reusable :class:`NumericsPolicy` plus a
report row per decision — the accuracy/cost frontier that
``benchmarks/run.py`` writes to ``BENCH_numerics.json``.

The multiplier-cost proxy mirrors ``benchmarks/hw_cost.py``'s unit-gate
model (array multiplier ~ quadratic in fraction bits; PLAM ~ one adder,
linear), weighted by per-token MAC counts per site — an *ordering*
heuristic and reporting column, not a synthesis result.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.modes import NumericsConfig
from repro.core.policy import (
    NumericsPolicy,
    Rule,
    as_policy,
    cfg_spec_str,
    layer_segments,
    load_policy_arg,
    parse_cfg_spec,
    policy_to_dict,
    policy_to_str,
    site,
    site_for,
)

# ---------------------------------------------------------------------------
# multiplier-cost model (unit-gate proxy, per scalar multiply)
# ---------------------------------------------------------------------------

_FA = 7.0  # full-adder gate equivalents (as in benchmarks/hw_cost.py)


def _codec_cost(n: int) -> float:
    # decode+encode: complement + LZC + two shifters + two adders
    return 2 * (_FA * n + 3.0 * n + 3.0 * n * max(1, math.ceil(math.log2(n))))


def unit_mult_cost(cfg: NumericsConfig) -> float:
    """Unit-gate area proxy for one scalar multiply under `cfg`."""
    if cfg.mode in ("f32", "mitchell_f32"):
        m = 24  # f32 significand
        return m * m + _FA * m * (m - 2)
    if cfg.mode == "bf16":
        m = 8
        return m * m + _FA * m * (m - 2)
    fb = cfg.n - 3 - cfg.es
    if cfg.mode == "posit_quant":  # exact posit multiplier
        m = fb + 1
        return _codec_cost(cfg.n) + m * m + _FA * m * (m - 2)
    if cfg.mode == "plam_sim":  # PLAM: the one adder replacing the mult
        w = fb + cfg.es + math.ceil(math.log2(cfg.n))
        return _codec_cost(cfg.n) + _FA * w
    raise ValueError(cfg.mode)


# ---------------------------------------------------------------------------
# per-site MAC counts (per token, forward pass)
# ---------------------------------------------------------------------------


def site_macs(cfg) -> Dict[str, float]:
    """Approximate per-token MACs for every matmul site of `cfg`.

    Used to weight the unit multiplier cost and to order the greedy
    walk; layer counts multiply in, role groups are summed leaves.
    """
    d, l = cfg.d_model, cfg.n_layers
    hd = cfg.hd
    macs: Dict[str, float] = {}
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        n_attn = l if cfg.family != "hybrid" else max(
            1, l // max(cfg.shared_attn_every, 1)
        )
        dd = d if cfg.family != "hybrid" else 2 * d
        macs["attn.qkv"] = n_attn * dd * (cfg.n_heads + 2 * cfg.n_kv) * hd
        macs["attn.out"] = n_attn * cfg.n_heads * hd * dd
    if cfg.family in ("dense", "vlm") or (cfg.family == "hybrid"):
        d_in = d if cfg.family != "hybrid" else 2 * d
        n_mlp = l if cfg.family != "hybrid" else max(
            1, l // max(cfg.shared_attn_every, 1)
        )
        macs["mlp.up"] = n_mlp * d_in * cfg.d_ff
        if cfg.glu:
            macs["mlp.gate"] = n_mlp * d_in * cfg.d_ff
        macs["mlp.down"] = n_mlp * cfg.d_ff * d_in
    if cfg.family == "moe":
        macs["moe.router"] = l * d * cfg.n_experts
        e = l * cfg.top_k * d * cfg.moe_d_ff
        macs["moe.expert.up"] = e
        macs["moe.expert.gate"] = e if cfg.glu else 0.0
        macs["moe.expert.down"] = e
        if cfg.n_shared_experts:
            s = l * d * cfg.moe_d_ff * cfg.n_shared_experts
            macs["moe.shared.up"] = s
            macs["moe.shared.gate"] = s if cfg.glu else 0.0
            macs["moe.shared.down"] = s
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * d
        nh = di // cfg.ssm_head_dim
        macs["ssm.proj.in"] = l * d * (2 * di + 2 * cfg.ssm_state + nh)
        macs["ssm.proj.out"] = l * di * d
    if cfg.family == "hybrid":
        macs["hybrid.proj"] = max(1, l // max(cfg.shared_attn_every, 1)) * 2 * d * d
    if cfg.family == "encdec":
        ltot = cfg.enc_layers + cfg.dec_layers
        macs["attn.qkv"] = ltot * d * (cfg.n_heads + 2 * cfg.n_kv) * hd
        macs["attn.out"] = ltot * cfg.n_heads * hd * d
        macs["attn.cross.qkv"] = cfg.dec_layers * d * (cfg.n_heads + 2 * cfg.n_kv) * hd
        macs["attn.cross.out"] = cfg.dec_layers * cfg.n_heads * hd * d
        macs["mlp.up"] = ltot * d * cfg.d_ff
        if cfg.glu:
            macs["mlp.gate"] = ltot * d * cfg.d_ff
        macs["mlp.down"] = ltot * cfg.d_ff * d
        if cfg.frontend_dim:
            macs["frontend"] = cfg.frontend_dim * d
    macs["lm_head"] = d * cfg.vocab
    return {k: v for k, v in macs.items() if v > 0}


def _layer_free_roles(cfg) -> frozenset:
    """Roles the models resolve without a layer index (so layers[] rules
    never apply): heads/frontends always, plus the hybrid family's
    shared attention/MLP block."""
    roles = {"lm_head", "frontend", "hybrid.proj"}
    if cfg.family in ("hybrid", "encdec"):
        roles |= {r for r in site_macs(cfg) if r.startswith(("attn.", "mlp."))}
    return frozenset(roles)


def _role_unit_cost(cfg, numerics, role, layer_free: bool) -> float:
    """Unit multiplier cost for one role, averaged over the layer stack
    when layer-range rules make it layer-dependent."""
    if layer_free:
        return unit_mult_cost(site_for(numerics, role, None, cfg.n_layers))
    total = 0.0
    for _, size, bound in layer_segments(numerics, cfg.n_layers):
        total += size * unit_mult_cost(site(bound, role))
    return total / cfg.n_layers


def estimate_cost(cfg, numerics=None) -> float:
    """Σ_site MACs × unit multiplier cost under `numerics` (defaults to
    cfg.numerics).  Comparable across policies of the SAME model.
    Layer-range rules are honored by averaging the per-layer unit cost
    over the stack (site MACs already include the layer multiplicity).
    """
    numerics = cfg.numerics if numerics is None else numerics
    layer_free = _layer_free_roles(cfg)
    total = 0.0
    for role, macs in site_macs(cfg).items():
        total += macs * _role_unit_cost(cfg, numerics, role, role in layer_free)
    return total


# ---------------------------------------------------------------------------
# greedy calibration
# ---------------------------------------------------------------------------


def default_candidate_sites(cfg) -> Tuple[str, ...]:
    """Role groups the greedy walk may reassign, for this family."""
    roles = list(site_macs(cfg))
    groups = []
    for g in ("mlp", "moe.expert", "moe.shared", "attn", "ssm.proj"):
        if any(r == g or r.startswith(g + ".") for r in roles):
            groups.append(g)
    if "lm_head" in roles:
        groups.append("lm_head")
    return tuple(groups)


def _group_macs(roles_macs: Dict[str, float], group: str) -> float:
    return sum(
        m for r, m in roles_macs.items()
        if r == group or r.startswith(group + ".")
    )


@dataclasses.dataclass
class CalibrationResult:
    policy: NumericsPolicy
    base_loss: float
    budget: float
    decisions: List[dict]

    @property
    def policy_str(self) -> str:
        return policy_to_str(self.policy)


def _eval_loss(cfg, params, batch) -> float:
    from repro.models import build

    api = build(cfg)
    return float(jax.jit(api.train_loss)(params, batch))


def calibrate(
    cfg,
    params,
    batch,
    *,
    budget: float = 0.02,
    base: str = "f32",
    target: str = "plam_sim:16:1",
    fallback: Optional[str] = "posit_quant:16:1",
    sites: Optional[Sequence[str]] = None,
) -> CalibrationResult:
    """Greedy budgeted site walk.  Returns the calibrated policy.

    budget: max relative eval-loss increase vs the all-`base` policy.
    Sites are visited in descending estimated multiplier-cost savings
    (the cheapest place to spend the budget first); each one keeps the
    `target` (PLAM) assignment if the loss stays within budget, else
    tries `fallback` (exact posit), else reverts to `base`.
    """
    base_cfg = parse_cfg_spec(base)
    target_cfg = parse_cfg_spec(target)
    fb_cfg = None if fallback is None else parse_cfg_spec(fallback)
    sites = tuple(sites) if sites is not None else default_candidate_sites(cfg)

    roles_macs = site_macs(cfg)
    savings = {
        g: _group_macs(roles_macs, g)
        * (unit_mult_cost(base_cfg) - unit_mult_cost(target_cfg))
        for g in sites
    }
    order = sorted(sites, key=lambda g: -savings[g])

    def policy_of(assign: Dict[str, NumericsConfig]) -> NumericsPolicy:
        rules = [Rule(role="", cfg=base_cfg)]
        rules += [Rule(role=g, cfg=c) for g, c in assign.items()]
        return NumericsPolicy(rules=tuple(rules))

    base_loss = _eval_loss(cfg.with_numerics(policy_of({})), params, batch)
    limit = base_loss + abs(base_loss) * budget

    assign: Dict[str, NumericsConfig] = {}
    decisions = []
    current_loss = base_loss  # loss of the configuration actually kept
    for g in order:
        choice, trials = base_cfg, []
        for cand in ([target_cfg, fb_cfg] if fb_cfg is not None else [target_cfg]):
            trial = dict(assign)
            trial[g] = cand
            loss = _eval_loss(cfg.with_numerics(policy_of(trial)), params, batch)
            trials.append({"cfg": cfg_spec_str(cand), "loss": loss})
            if loss <= limit:
                choice = cand
                current_loss = loss
                break
        if choice is not base_cfg:
            assign[g] = choice
        decisions.append({
            "site": g,
            "assigned": cfg_spec_str(choice),
            "loss": current_loss,
            "trials": trials,
            "est_savings": savings[g],
        })

    return CalibrationResult(
        policy=policy_of(assign),
        base_loss=base_loss,
        budget=budget,
        decisions=decisions,
    )


# ---------------------------------------------------------------------------
# policy artifacts
# ---------------------------------------------------------------------------

ARTIFACT_FORMAT = "plam-numerics-policy/v1"


def save_policy_artifact(path: str, policy, report: Optional[dict] = None) -> None:
    """Write a reusable policy artifact (JSON) consumable by
    ``--numerics-policy`` in launch/serve.py and launch/dryrun.py."""
    policy = as_policy(policy)
    data = {
        "format": ARTIFACT_FORMAT,
        "policy": policy_to_dict(policy),
        "policy_str": policy_to_str(policy),
        "report": report or {},
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2)


def load_policy_artifact(path: str) -> NumericsPolicy:
    """Load a saved artifact via the CLI loader (one parser for the
    schema); unlike load_policy_arg, a missing file is an error rather
    than a policy-string fallback."""
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    return load_policy_arg(path)


def top1_agreement(logits_a, logits_b) -> float:
    """Fraction of positions where two logit tensors argmax-agree."""
    a = np.argmax(np.asarray(logits_a, np.float32), axis=-1)
    b = np.argmax(np.asarray(logits_b, np.float32), axis=-1)
    return float(np.mean(a == b))
