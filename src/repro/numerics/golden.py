"""Pure-Python golden reference for Posit<n,es> arithmetic.

This module is intentionally written with string/bit manipulation over
Python ints and floats (exact for n <= 32 via float64), independent of
the vectorized JAX implementation in ``posit.py``.  It is the oracle the
JAX codec, the exhaustive lookup tables, and the multiplier tests are
validated against.

Conventions
-----------
* A posit is an ``n``-bit pattern held in a Python int ``0 <= p < 2**n``.
* ``0`` is zero, ``1 << (n-1)`` is NaR (mapped to float ``nan``).
* Values follow eq. (1) of the paper:
  ``X = (-1)^s * (2^(2^es))^k * 2^e * (1 + f)``.
"""
from __future__ import annotations

import math
from functools import lru_cache

__all__ = [
    "decode_py",
    "encode_py",
    "plam_mul_py",
    "exact_mul_py",
    "decode_fields_py",
    "all_values",
]


def decode_fields_py(p: int, n: int, es: int):
    """Return (sign, k, e, f) for a non-zero, non-NaR pattern."""
    s = (p >> (n - 1)) & 1
    if s:
        p = ((1 << n) - p) & ((1 << n) - 1)
    body = p & ((1 << (n - 1)) - 1)
    bits = format(body, f"0{n - 1}b")
    r0 = bits[0]
    run = len(bits) - len(bits.lstrip(r0))
    k = run - 1 if r0 == "1" else -run
    rest = bits[run + 1:]  # after the terminator bit (may be empty)
    ebits = rest[:es].ljust(es, "0")  # missing low exponent bits are 0
    e = int(ebits, 2) if es else 0
    fbits = rest[es:]
    f = int(fbits, 2) / (1 << len(fbits)) if fbits else 0.0
    return s, k, e, f


def decode_py(p: int, n: int, es: int) -> float:
    """Decode an n-bit posit pattern to float64 (exact for n <= 32)."""
    p &= (1 << n) - 1
    if p == 0:
        return 0.0
    if p == 1 << (n - 1):
        return math.nan
    s, k, e, f = decode_fields_py(p, n, es)
    return (-1.0) ** s * 2.0 ** (k * (1 << es) + e) * (1.0 + f)


@lru_cache(maxsize=8)
def all_values(n: int, es: int):
    """Values of all positive patterns 1 .. 2^(n-1)-1 (monotone)."""
    return [decode_py(p, n, es) for p in range(1, 1 << (n - 1))]


@lru_cache(maxsize=8)
def thresholds(n: int, es: int):
    """Pattern-RNE rounding thresholds between consecutive n-bit posits.

    SoftPosit (and the 2022 standard) round the assembled *bit pattern*
    to nearest-even.  The threshold between bodies j and j+1 is exactly
    the value of the odd (n+1)-bit posit pattern 2j+1 that sits between
    them (append one bit: round-bit set, sticky clear).  Within a
    binade this equals the arithmetic midpoint; across multi-binade
    regime gaps (near minpos/maxpos) it is the geometric-ish pattern
    midpoint — which is where naive value-nearest rounding diverges.
    """
    vals_wide = all_values(n + 1, es)
    # body t between n-bit bodies j, j+1 is t = 2j+1 -> index 2j in vals_wide
    return [vals_wide[2 * j] for j in range(1, (1 << (n - 1)) - 1)]


def encode_py(x: float, n: int, es: int) -> int:
    """Round float -> posit pattern (SoftPosit pattern-space RNE).

    Saturates at +-maxpos; magnitudes below minpos round to minpos
    (posits never round a non-zero value to zero or NaR).
    """
    if math.isnan(x) or math.isinf(x):
        return 1 << (n - 1)
    if x == 0.0:
        return 0
    s = x < 0
    a = abs(x)
    ths = thresholds(n, es)
    import bisect

    i = bisect.bisect_left(ths, a)  # ths[i-1] < a <= ths[i]
    body = i + 1
    if i < len(ths) and a == ths[i]:  # exact tie -> even pattern
        if body % 2 == 1:
            body += 1
    body = min(body, (1 << (n - 1)) - 1)
    p = body
    if s:
        p = ((1 << n) - p) & ((1 << n) - 1)
    return p


def plam_mul_py(pa: int, pb: int, n: int, es: int) -> int:
    """PLAM multiplication, eqs. (14)-(21): fraction product -> sum."""
    nar = 1 << (n - 1)
    pa &= (1 << n) - 1
    pb &= (1 << n) - 1
    if pa == nar or pb == nar:
        return nar
    if pa == 0 or pb == 0:
        return 0
    sa, ka, ea, fa = decode_fields_py(pa, n, es)
    sb, kb, eb, fb = decode_fields_py(pb, n, es)
    s = sa ^ sb
    f = fa + fb  # eq. (17): log-approximate fraction "product"
    scale = (ka + kb) * (1 << es) + (ea + eb)
    if f >= 1.0:  # eqs. (19)-(21): carry folds into exponent/regime
        f -= 1.0
        scale += 1
    val = 2.0 ** scale * (1.0 + f)
    return encode_py(-val if s else val, n, es)


def exact_mul_py(pa: int, pb: int, n: int, es: int) -> int:
    """Exact posit multiplication, eqs. (3)-(10), via float64.

    Exact for n <= 16 (fraction product <= 26 significant bits << 53).
    """
    nar = 1 << (n - 1)
    pa &= (1 << n) - 1
    pb &= (1 << n) - 1
    if pa == nar or pb == nar:
        return nar
    if pa == 0 or pb == 0:
        return 0
    va = decode_py(pa, n, es)
    vb = decode_py(pb, n, es)
    return encode_py(va * vb, n, es)
