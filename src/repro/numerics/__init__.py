"""Posit<n,es> arithmetic + PLAM (the paper's core) in pure JAX."""
from .posit import (  # noqa: F401
    P8,
    P16,
    P32,
    PositSpec,
    decode,
    decode_fields,
    encode,
    encode_fields,
    pack16,
    quantize,
    unpack16,
)
from .plam import (  # noqa: F401
    exact_mul,
    mitchell_mul_f32,
    plam_mul,
    plam_mul_logfix,
    plam_product_f32,
    plam_relative_error,
)
from .table import decode_table, encode_table, tables  # noqa: F401
