"""PLAM — Posit Logarithm-Approximate Multiplication (paper Sec. III).

Three equivalent implementations of the paper's multiplier, plus the
exact posit multiplier it replaces:

* :func:`plam_mul`        — field-equation path, eqs. (14)-(21).
* :func:`plam_mul_logfix` — the Fig. 4 hardware path: concatenate
  regime|exponent|fraction into one fixed-point log word, add, re-encode.
  (Demonstrated for n <= 16 where the word fits 32 bits; for wider
  posits the field path is the same algebra split across two words.)
* :func:`plam_product_f32` — PLAM product decoded straight to linear
  float32 *without* re-encoding, for EMAC-style linear accumulation in
  dot products.  This is the TPU-native trick: Mitchell's antilogarithm
  is exactly the IEEE-754 bit layout, so the entire product is one
  integer add plus a bitcast.
* :func:`exact_mul`       — eqs. (3)-(10), bit-exact for n <= 16.

Error analysis utilities implement eq. (24) (max relative error 1/9).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .posit import I32, U32, PositSpec, decode_fields, encode_fields, _shl

__all__ = [
    "plam_mul",
    "plam_mul_logfix",
    "plam_product_f32",
    "exact_mul",
    "mitchell_mul_f32",
    "plam_relative_error",
]


def _special(cand, a_bits, b_bits, spec, az, an, bz, bn):
    """Fold zero/NaR handling into a computed pattern."""
    out = jnp.where(az | bz, I32(0), cand)
    out = jnp.where(an | bn, I32(spec.nar), out)
    return out


@partial(jax.jit, static_argnames=("spec",))
def plam_mul(a_bits, b_bits, spec: PositSpec):
    """PLAM product of two posit patterns -> posit pattern (eqs. 14-21)."""
    fb = spec.fbmax
    sa, ca, fa, az, an = decode_fields(a_bits, spec)
    sb, cb, fbr, bz, bn = decode_fields(b_bits, spec)
    s = sa ^ sb                                   # eq. (14)
    fsum = fa + fbr                               # eq. (17): product -> sum
    carry = fsum >> I32(fb)                       # eqs. (19)-(21) overflow
    frac = fsum & I32((1 << fb) - 1)
    scale = ca + cb + carry                       # eqs. (15)-(16) + carry
    cand = encode_fields(s, scale, frac.astype(U32), fb, spec)
    return _special(cand, a_bits, b_bits, spec, az, an, bz, bn)


@partial(jax.jit, static_argnames=("spec",))
def plam_mul_logfix(a_bits, b_bits, spec: PositSpec):
    """PLAM via the Fig. 4 hardware datapath (single log-fixed word).

    log2|X| ~= (k*2^es + e) + f  ==  (scale << fb) | frac  as a fixed
    point integer with fb fractional bits.  The multiplication is ONE
    integer addition of these words; the carry out of the fraction
    propagates into exponent/regime automatically — exactly the point
    of the paper's hardware design.
    """
    fb = spec.fbmax
    # scale range * 2^fb must fit int32
    assert (2 * spec.max_scale + 2) < (1 << (30 - fb)), "logfix word overflow"
    sa, ca, fa, az, an = decode_fields(a_bits, spec)
    sb, cb, fbr, bz, bn = decode_fields(b_bits, spec)
    la = (ca << I32(fb)) | fa
    lb = (cb << I32(fb)) | fbr
    lsum = la + lb                                # the whole multiplier
    scale = lsum >> I32(fb)                       # arithmetic shift: floor
    frac = (lsum & I32((1 << fb) - 1)).astype(U32)
    cand = encode_fields(sa ^ sb, scale, frac, fb, spec)
    return _special(cand, a_bits, b_bits, spec, az, an, bz, bn)


@partial(jax.jit, static_argnames=("spec",))
def plam_product_f32(a_bits, b_bits, spec: PositSpec):
    """PLAM product decoded directly to linear float32 (no re-encode).

    Used for EMAC/Johnson-style dot products: products are antilogged
    and accumulated in linear f32.  Mitchell's antilog of the summed
    log-fixed word IS the f32 bit layout: exponent <- integer part,
    mantissa <- fractional part.  Integer add + bitcast, no multiplier.
    """
    fb = spec.fbmax
    sa, ca, fa, az, an = decode_fields(a_bits, spec)
    sb, cb, fbr, bz, bn = decode_fields(b_bits, spec)
    s = (sa ^ sb).astype(U32)
    fsum = fa + fbr
    carry = fsum >> I32(fb)
    frac = (fsum & I32((1 << fb) - 1)).astype(U32)
    scale = ca + cb + carry
    scale = jnp.clip(scale, -126, 127)  # f32-representable (posit32 tails saturate)
    if fb <= 23:
        mant = frac << U32(23 - fb)
    else:
        mant = frac >> U32(fb - 23)
    bits32 = (s << U32(31)) | ((scale + I32(127)).astype(U32) << U32(23)) | mant
    val = jax.lax.bitcast_convert_type(bits32, jnp.float32)
    val = jnp.where(az | bz | an | bn, jnp.float32(0), val)  # NaR excluded upstream
    return val


@partial(jax.jit, static_argnames=("spec",))
def exact_mul(a_bits, b_bits, spec: PositSpec):
    """Exact posit multiplication (eqs. 3-10), bit-exact RNE, n <= 16.

    The fraction product (1+fa)(1+fb) needs 2*fbmax+2 bits; together
    with the es bits in the rounding word this must fit 32 bits, which
    holds for n <= 16.  (Wider exact multiplication is provided by the
    float64 golden reference; PLAM itself — the paper's contribution —
    never needs the wide product, which is exactly its hardware point.)
    """
    fb = spec.fbmax
    assert 2 * fb + 1 + spec.es <= 30, "exact_mul supports n <= 16"
    sa, ca, fa, az, an = decode_fields(a_bits, spec)
    sb, cb, fbr, bz, bn = decode_fields(b_bits, spec)
    s = sa ^ sb
    one = I32(1 << fb)
    prod = (one | fa) * (one | fbr)               # eq. (6), in [2^2fb, 2^(2fb+2))
    ovf = (prod >> I32(2 * fb + 1)) & I32(1)      # product >= 2 ?
    scale = ca + cb + ovf                         # eqs. (4),(5),(8),(9)
    # Normalize to a uniform 2fb+1-bit fraction (hidden bit stripped);
    # the no-overflow case gains a zero low bit — value-preserving.
    frac = jnp.where(
        ovf == 1,
        prod - I32(1 << (2 * fb + 1)),
        _shl(
            (prod - I32(1 << (2 * fb))).astype(U32), jnp.full_like(prod, 1)
        ).astype(I32),
    ).astype(U32)
    cand = encode_fields(s, scale, frac, 2 * fb + 1, spec)
    return _special(cand, a_bits, b_bits, spec, az, an, bz, bn)


@jax.jit
def mitchell_mul_f32(a, b):
    """Float-domain Mitchell multiplier (the Cheng et al. [20] baseline).

    Treats the f32 exponent|mantissa bits as a fixed-point log2: the
    approximate product is (bits_a - BIAS) + (bits_b - BIAS) + BIAS,
    bitcast back, with the sign handled by XOR.  Used as the
    floating-point counterpart PLAM is compared against.
    """
    bias = U32(127 << 23)
    ba = jax.lax.bitcast_convert_type(a.astype(jnp.float32), U32)
    bb = jax.lax.bitcast_convert_type(b.astype(jnp.float32), U32)
    s = (ba ^ bb) & U32(0x80000000)
    la = ba & U32(0x7FFFFFFF)
    lb = bb & U32(0x7FFFFFFF)
    lc = la + lb - bias
    out = jax.lax.bitcast_convert_type(s | lc, jnp.float32)
    return jnp.where((la == 0) | (lb == 0), jnp.float32(0), out)


@partial(jax.jit, static_argnames=("spec",))
def plam_relative_error(a_bits, b_bits, spec: PositSpec):
    """Analytic relative error of PLAM, eq. (24) — depends only on fractions."""
    fb = spec.fbmax
    _, _, fa, _, _ = decode_fields(a_bits, spec)
    _, _, fbr, _, _ = decode_fields(b_bits, spec)
    fa = fa.astype(jnp.float32) / (1 << fb)
    fbv = fbr.astype(jnp.float32) / (1 << fb)
    no_carry = fa + fbv < 1.0
    err = jnp.where(
        no_carry,
        fa * fbv / ((1 + fa) * (1 + fbv)),
        (1 - fa) * (1 - fbv) / ((1 + fa) * (1 + fbv)),
    )
    return err
