"""Exhaustive-table Posit codec for n <= 16.

Independent of the bit-twiddling codec in ``posit.py``: tables are built
from the pure-Python golden decoder, and rounding is value-space
nearest-with-ties-to-even-pattern.  For posits these two formulations
(pattern-space RNE vs value-space nearest) coincide — the tests assert
agreement between this codec and ``posit.py`` as a strong invariant.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from .golden import all_values, thresholds
from .posit import I32, PositSpec

__all__ = ["decode_table", "encode_table", "tables"]


@lru_cache(maxsize=8)
def tables(n: int, es: int):
    """(values f32, rounding thresholds f32) for positive bodies 1..maxpos.

    Thresholds are the pattern-RNE boundaries (odd (n+1)-bit posits),
    exact in f32 since they carry <= n-1 significand bits.
    """
    assert n <= 16, "exhaustive tables are for n <= 16"
    vals = np.asarray(all_values(n, es), dtype=np.float64)
    mids = np.asarray(thresholds(n, es), dtype=np.float64)
    # numpy (not jnp) so the lru_cache never captures tracers
    return vals.astype(np.float32), mids.astype(np.float32)


@partial(jax.jit, static_argnames=("spec",))
def decode_table(bits, spec: PositSpec):
    vals_np, _ = tables(spec.n, spec.es)
    vals = jnp.asarray(vals_np)
    u = bits.astype(jnp.uint32) & jnp.uint32(spec.mask_n)
    sign = (u >> jnp.uint32(spec.n - 1)) != 0
    mag = jnp.where(sign, (jnp.uint32(0) - u) & jnp.uint32(spec.mask_n), u)
    body = (mag & jnp.uint32(spec.maxpos_body)).astype(I32)
    v = vals[jnp.clip(body - 1, 0, vals.shape[0] - 1)]
    v = jnp.where(sign, -v, v)
    v = jnp.where(u == 0, jnp.float32(0), v)
    v = jnp.where(u == jnp.uint32(spec.nar), jnp.float32(jnp.nan), v)
    return v


@partial(jax.jit, static_argnames=("spec",))
def encode_table(x, spec: PositSpec):
    """float32 -> posit pattern via midpoint binary search."""
    vals_np, mids_np = tables(spec.n, spec.es)
    vals, mids = jnp.asarray(vals_np), jnp.asarray(mids_np)
    x32 = x.astype(jnp.float32)
    # The bitcast must be the ONLY consumer of x32, mirroring
    # posit.encode: XLA CPU executes with denormals-are-zero, and when
    # a fused kLoop shares the parameter load between fp ops and a
    # bitcast-convert, the bitcast sees the DAZ-flushed value — a
    # subnormal input would read as +0.0 bits.  But posits never round
    # a nonzero magnitude to zero (it saturates to minpos), so zero /
    # NaR / sign all come from the raw bits, and |x| for the threshold
    # search is RECONSTRUCTED from the magnitude bits.  (A subnormal
    # |x| still lands on body 1 = minpos because DAZ makes the
    # searchsorted compares see it below mids[0].)
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    sign = (bits >> jnp.uint32(31)) != 0
    is_zero = (bits & jnp.uint32(0x7FFFFFFF)) == jnp.uint32(0)
    is_nar = ((bits >> jnp.uint32(23)) & jnp.uint32(0xFF)) == jnp.uint32(0xFF)
    a = jax.lax.bitcast_convert_type(
        bits & jnp.uint32(0x7FFFFFFF), jnp.float32
    )
    j = jnp.searchsorted(mids, a, side="left").astype(I32)
    # mids[j-1] < a <= mids[j]  ->  candidate body j+1 (vals[j]);
    # exact tie a == mids[j] -> even pattern among bodies {j+1, j+2}.
    tie = a == mids[jnp.clip(j, 0, mids.shape[0] - 1)]
    body = j + 1
    body = jnp.where(tie & (body % 2 == 1), body + 1, body)
    body = jnp.clip(body, 1, spec.maxpos_body)
    pat = jnp.where(
        sign,
        (jnp.uint32(0) - body.astype(jnp.uint32)) & jnp.uint32(spec.mask_n),
        body.astype(jnp.uint32),
    ).astype(I32)
    pat = jnp.where(is_zero, I32(0), pat)
    pat = jnp.where(is_nar, I32(spec.nar), pat)
    return pat
