"""Pure-jnp oracles for the Pallas kernels.

These are the semantic references the kernels must match bit-for-bit
(`assert_allclose` with tight tolerances in tests).  They are written
for clarity, not speed — full [M, K, N] broadcasts — so keep shapes
small when calling them.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.numerics import PositSpec, decode, encode, plam_product_f32


@partial(jax.jit, static_argnames=("spec",))
def plam_matmul_ref(a_bits, b_bits, spec: PositSpec):
    """EMAC-style PLAM matmul oracle.

    C[m, n] = sum_k PLAM(A[m, k], B[k, n]) with each approximate product
    antilogged to linear f32 and accumulated in f32 (Johnson-style
    linear accumulation; the paper's DNN experiments do the same via
    Deep PeNSieve's fused dot).
    """
    prods = plam_product_f32(a_bits[:, :, None], b_bits[None, :, :], spec)
    return jnp.sum(prods, axis=1, dtype=jnp.float32)


@partial(jax.jit, static_argnames=("spec",))
def plam_matmul_seqref(a_bits, b_bits, spec: PositSpec):
    """Sequential-k PLAM matmul: BIT-identical to the Pallas kernel.

    ``plam_matmul_ref`` reduces with ``jnp.sum``, whose f32 reduction
    order XLA does not pin down, so it is only allclose to the kernel.
    This reference accumulates k strictly ascending — the order the
    kernel's ``fori_loop`` walks lanes within and across K blocks — so
    ``np.array_equal`` comparisons are valid for any (M, N, K), ragged
    or not.  The kernel's zero-padding lanes add exactly +0.0 and both
    accumulators start at +0.0, so padding never perturbs a bit.
    """
    prods = plam_product_f32(a_bits[:, :, None], b_bits[None, :, :], spec)
    m, k, n = prods.shape
    acc0 = jnp.zeros((m, n), jnp.float32)

    def body(i, acc):
        return acc + prods[:, i, :]

    return jax.lax.fori_loop(0, k, body, acc0)


@partial(jax.jit, static_argnames=("spec",))
def plam_dense_ref(x, w_bits, spec: PositSpec):
    """x (f32 [M,K]) @ posit-weights (bits [K,N]): quantize x, PLAM-matmul."""
    return plam_matmul_ref(encode(x, spec), w_bits, spec)


@partial(jax.jit, static_argnames=("spec",))
def posit_quantize_ref(x, spec: PositSpec):
    """Project f32 onto the posit grid (decode(encode(x)))."""
    return decode(encode(x, spec), spec)
