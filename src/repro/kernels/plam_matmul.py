"""Pallas TPU kernel for the PLAM matrix multiplier.

TPU-native adaptation of the paper's Fig. 4 datapath (see DESIGN.md §3):

* A posit's (regime‖exponent‖fraction) is a fixed-point log2 of its
  magnitude.  We decode each operand tile once into "f32-aligned log
  words"  L = (scale + 127) << 23 | mantissa23  — i.e. the log-fixed
  point *in the position of the IEEE-754 exponent/mantissa fields*.
* A PLAM product is then ONE integer add (La_pre + Lb, with the bias
  pre-subtracted from A's words) followed by a BITCAST to f32 —
  Mitchell's antilogarithm is exactly the float bit layout.  No
  multiplier is used anywhere, mirroring the paper's hardware claim.
* Products accumulate in linear f32 (EMAC / Johnson-style).

The kernel runs on the VPU (element-wise integer adds), not the MXU:
it is the *simulation engine* for posit-hardware studies, and its
roofline is the VPU add throughput, which this layout saturates.

Grid: (M/bm, N/bn, K/bk), K innermost for in-place accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams

from repro.numerics import PositSpec
from repro.numerics.posit import I32, U32, decode_fields

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128
_BIAS = 127 << 23


def _log_words(bits, spec: PositSpec):
    """Posit patterns -> (sign<<31 words, f32-aligned log magnitudes, valid).

    zero/NaR inputs are marked invalid; their products contribute 0.
    """
    fb = spec.fbmax
    sign, scale, frac, is_zero, is_nar = decode_fields(bits, spec)
    if fb <= 23:
        mant = frac.astype(U32) << U32(23 - fb)
    else:
        mant = frac.astype(U32) >> U32(fb - 23)
    lmag = ((scale + I32(127)).astype(U32) << U32(23)) | mant
    s31 = sign.astype(U32) << U32(31)
    valid = ~(is_zero | is_nar)
    return s31, lmag.astype(I32), valid


def _plam_matmul_kernel(a_ref, b_ref, o_ref, *, spec: PositSpec, bk: int):
    """One (bm, bn) output tile; a_ref (bm, bk) int32, b_ref (bk, bn) int32."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Element-wise decode of both tiles: O(bm*bk + bk*bn) integer ops.
    sa, la, va = _log_words(a_ref[...], spec)
    sb, lb, vb = _log_words(b_ref[...], spec)
    la_pre = la - I32(_BIAS)  # pre-subtract the bias once per A element

    acc = o_ref[...]

    def body(k, acc):
        # [bm,1] x [1,bn] broadcasts: per pair ONE add + bitcast (+mask).
        lsum = la_pre[:, k][:, None] + lb[k, :][None, :]
        sgn = sa[:, k][:, None] ^ sb[k, :][None, :]
        bits = sgn | lsum.astype(U32)
        val = jax.lax.bitcast_convert_type(bits, jnp.float32)
        ok = va[:, k][:, None] & vb[k, :][None, :]
        return acc + jnp.where(ok, val, jnp.float32(0))

    acc = jax.lax.fori_loop(0, bk, body, acc)
    o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("spec", "bm", "bn", "bk", "interpret")
)
def plam_matmul(
    a_bits,
    b_bits,
    spec: PositSpec = PositSpec(16, 1),
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
):
    """C = A ⊗_PLAM B with linear-f32 accumulation.

    a_bits: int32 [M, K] posit patterns;  b_bits: int32 [K, N].
    Shapes are padded to block multiples (pattern 0 == posit zero, whose
    products are exactly zero, so padding is value-preserving).
    """
    assert spec.max_scale * 2 + 127 <= 254, "spec's product scale must fit f32"
    m, k = a_bits.shape
    k2, n = b_bits.shape
    assert k == k2, (a_bits.shape, b_bits.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)

    def pad(x, mult0, mult1):
        p0 = (-x.shape[0]) % mult0
        p1 = (-x.shape[1]) % mult1
        if p0 or p1:
            x = jnp.pad(x, ((0, p0), (0, p1)))
        return x

    a_p = pad(a_bits, bm_, bk_)
    b_p = pad(b_bits, bk_, bn_)
    mp, kp = a_p.shape
    _, np_ = b_p.shape

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_plam_matmul_kernel, spec=spec, bk=bk_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
