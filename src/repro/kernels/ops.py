"""Public jit'd wrappers over the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel
body executes as pure jnp on the host, which validates correctness; on
TPU the same code lowers through Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.numerics import PositSpec, encode

from . import plam_matmul as _pm
from . import posit_codec as _pc


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def plam_matmul_bits(a_bits, b_bits, spec: PositSpec = PositSpec(16, 1), **kw):
    """PLAM matmul over posit patterns -> f32."""
    kw.setdefault("interpret", _interpret_default())
    return _pm.plam_matmul(a_bits, b_bits, spec, **kw)


def plam_dense(x, w_bits, spec: PositSpec = PositSpec(16, 1), **kw):
    """f32 activations x posit-pattern weights via the PLAM kernel.

    Activations are posit-quantized (encoded) on the fly; weights are
    stored pre-encoded — the deployment layout for posit inference.
    Leading batch dims of x are flattened into M.
    """
    kw.setdefault("interpret", _interpret_default())
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _pm.plam_matmul(encode(x2, spec), w_bits, spec, **kw)
    return out.reshape(*lead, w_bits.shape[-1])


def plam_mul_elementwise(a_bits, b_bits, spec: PositSpec = PositSpec(16, 1), **kw):
    """Element-wise PLAM pattern product (conformance oracle surface)."""
    kw.setdefault("interpret", _interpret_default())
    return _pc.plam_mul_elementwise(a_bits, b_bits, spec, **kw)


def exact_mul_elementwise(a_bits, b_bits, spec: PositSpec = PositSpec(16, 1), **kw):
    """Element-wise exact posit pattern product (n <= 16)."""
    kw.setdefault("interpret", _interpret_default())
    return _pc.exact_mul_elementwise(a_bits, b_bits, spec, **kw)


def posit_encode(x, spec: PositSpec = PositSpec(16, 1), **kw):
    kw.setdefault("interpret", _interpret_default())
    return _pc.posit_encode(x, spec, **kw)


def posit_decode(bits, spec: PositSpec = PositSpec(16, 1), **kw):
    kw.setdefault("interpret", _interpret_default())
    return _pc.posit_decode(bits, spec, **kw)


def posit_quantize(x, spec: PositSpec = PositSpec(16, 1), **kw):
    kw.setdefault("interpret", _interpret_default())
    return _pc.posit_quantize(x, spec, **kw)
