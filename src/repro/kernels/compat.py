"""Pallas API compatibility across JAX versions.

jax <= 0.4.x names the TPU compiler-params dataclass
``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams``.  Kernels import the alias from here so the
repo runs against both (CI pins one version, local installs vary).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None))
assert CompilerParams is not None, "unsupported Pallas TPU API"
