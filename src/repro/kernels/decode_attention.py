"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Motivated directly by the §Perf hillclimb (EXPERIMENTS.md): the XLA
lowering of decode attention materializes transposed copies and
convert round-trips of the cache slice per layer, and an XLA-level
blockwise scan round-trips its online-softmax accumulator through HBM.
This kernel streams KV blocks through VMEM with the (m, l, acc) state
held in VMEM scratch — one HBM read of the cache, no score
materialization: the true "flash-decode" data movement.

Grid: (B, S/blk) — batch parallel, KV blocks sequential (innermost) so
the running softmax state lives across grid steps in scratch.
q: [B, H, hd];  k,v: [B, S, kv, hd];  lengths: [B] valid cache length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_mesh

from .compat import CompilerParams

DEFAULT_BLOCK = 512


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, blk, kv, group, hd):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32).reshape(kv, group, hd) * hd ** -0.5
    k = k_ref[0].astype(jnp.float32)  # [blk, kv, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jnp.einsum("kgh,skh->kgs", q, k)  # [kv, group, blk]
    k_idx = si * blk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk), 2)
    valid = k_idx < len_ref[0]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_new = l_prev * scale + jnp.sum(p, axis=-1)
    acc = acc_ref[...] * scale[..., None] + jnp.einsum("kgs,skh->kgh", p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(si == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc / l_new[..., None]).reshape(kv * group, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk", "interpret"))
def decode_attention(q, k, v, lengths, *, blk: int = DEFAULT_BLOCK, interpret: bool = False):
    """q: [B, H, hd]; k, v: [B, S, kv, hd]; lengths: [B] int32.

    Returns [B, H, hd].  S is padded to a block multiple (padded keys
    masked by `lengths`).
    """
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    blk = min(blk, s)
    pad = (-s) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = k.shape[1]
    grid = (b, sp // blk)
    return pl.pallas_call(
        functools.partial(_kernel, blk=blk, kv=kv, group=group, hd=hd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, si: (bi,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, h, hd), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, blk, kv, hd), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, blk, kv, hd), lambda bi, si: (bi, si, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kv, group), jnp.float32),
            pltpu.VMEM((kv, group), jnp.float32),
            pltpu.VMEM((kv, group, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)


def decode_attention_ref(q, k, v, lengths):
    """Pure-jnp oracle."""
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.astype(jnp.float32).reshape(b, kv, group, hd) * hd ** -0.5
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode attention: KV lives in a block pool, indexed per sequence
# through a block table (the continuous-batching serving layout).
# ---------------------------------------------------------------------------


def _paged_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, bs, kv, group, hd):
    """Same online-softmax state machine as `_kernel`, but each grid step's
    KV block is fetched from the pool at `bt_ref[bi, si]` (scalar-prefetched
    block table) instead of a contiguous slice."""
    bi = pl.program_id(0)
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32).reshape(kv, group, hd) * hd ** -0.5
    k = k_ref[0].astype(jnp.float32)  # [bs, kv, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jnp.einsum("kgh,skh->kgs", q, k)  # [kv, group, bs]
    k_idx = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = k_idx < len_ref[bi]
    s = jnp.where(valid, s, -1e30)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_new = l_prev * scale + jnp.sum(p, axis=-1)
    acc = acc_ref[...] * scale[..., None] + jnp.einsum("kgs,skh->kgh", p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(si == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc / l_new[..., None]).reshape(kv * group, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_kernel(q, k_pool, v_pool, block_tables, lengths,
                                  *, interpret: bool = False):
    """Pallas paged decode attention.

    q: [B, H, hd]; k_pool, v_pool: [num_blocks, bs, kv, hd];
    block_tables: [B, max_blk] int32 pool indices (row-padded with any
    valid block id); lengths: [B] int32 valid key count per sequence.

    The block table rides the scalar-prefetch channel, so the BlockSpec
    index map dereferences it on the fly — the kernel streams exactly
    the pool blocks each sequence owns, never a contiguous copy.
    """
    b, h, hd = q.shape
    bs, kv = k_pool.shape[1], k_pool.shape[2]
    group = h // kv
    max_blk = block_tables.shape[1]
    grid = (b, max_blk)
    spec_kv = pl.BlockSpec(
        (1, bs, kv, hd), lambda bi, si, lens, bt: (bt[bi, si], 0, 0, 0))
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, kv=kv, group=group, hd=hd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, h, hd), lambda bi, si, lens, bt: (bi, 0, 0)),
                spec_kv,
                spec_kv,
            ],
            out_specs=pl.BlockSpec((1, h, hd), lambda bi, si, lens, bt: (bi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kv, group), jnp.float32),
                pltpu.VMEM((kv, group), jnp.float32),
                pltpu.VMEM((kv, group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, block_tables, q, k_pool, v_pool)


def gather_pages(pool, block_tables):
    """[num_blocks, bs, kv, hd] pool + [B, max_blk] table ->
    contiguous [B, max_blk*bs, kv, hd] per-sequence cache view."""
    b, max_blk = block_tables.shape
    bs, kv, hd = pool.shape[1:]
    pages = jnp.take(pool, block_tables.reshape(-1), axis=0)
    return pages.reshape(b, max_blk * bs, kv, hd)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """Gather-then-attend oracle (the CPU / interpret-free path).

    Matches `attn_core`'s operation order exactly (einsum then scale,
    f32 softmax, weights cast to the value dtype) so paged decode is
    token-identical to the monolithic-cache engine under greedy decode.
    """
    b, h, hd = q.shape
    kv = k_pool.shape[2]
    group = h // kv
    k = gather_pages(k_pool, block_tables)  # [B, S, kv, hd]
    v = gather_pages(v_pool, block_tables)
    s = k.shape[1]
    qg = q.reshape(b, 1, kv, group, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    logits *= hd ** -0.5
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    logits = jnp.where(mask[:, None, None, None, :], logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, h, hd)


def paged_decode_attention_tp(q, k_pool, v_pool, block_tables, lengths, mesh,
                              *, use_kernel: bool | None = None,
                              interpret: bool = False):
    """Head-sharded paged decode attention under tensor parallelism.

    shard_map over the mesh ``model`` axis: each device runs the paged
    kernel (or the gather oracle) on its local kv-head slice of the
    pool and the matching q-head slice — no collectives, because GQA
    groups q heads contiguously by kv head, so shard i's q heads attend
    exactly shard i's kv heads.  Block tables and lengths are
    replicated scalars/rows, same values on every shard.

    Requires kv % tp == 0 (the caller falls back to the GSPMD gather
    path, with the pool sharded on positions via ``seq_tp``, otherwise).
    """
    from jax.experimental.shard_map import shard_map

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    def local(q_l, kp_l, vp_l, bt, lens):
        if use_kernel:
            return paged_decode_attention_kernel(
                q_l, kp_l, vp_l, bt, lens, interpret=interpret)
        return paged_decode_attention_ref(q_l, kp_l, vp_l, bt, lens)

    pool_spec = P(None, None, "model", None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), pool_spec, pool_spec,
                  P(None, None), P(None)),
        out_specs=P(None, "model", None),
        check_rep=False,
    )(q, k_pool, v_pool, block_tables, lengths)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                           *, use_kernel: bool | None = None,
                           interpret: bool = False):
    """Paged decode attention, auto-dispatched.

    `use_kernel=None` picks the Pallas kernel on TPU and the jnp gather
    path elsewhere (the kernel also runs anywhere under interpret=True).
    Under an active TP mesh the head-sharded shard_map path is used when
    the kv heads divide the model axis; otherwise the gather path runs
    and GSPMD partitions it over whatever axis the pool is sharded on.
    """
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        tp = mesh.shape["model"]
        h, kv = q.shape[1], k_pool.shape[2]
        if tp > 1 and kv % tp == 0 and h % tp == 0:
            return paged_decode_attention_tp(
                q, k_pool, v_pool, block_tables, lengths, mesh,
                use_kernel=use_kernel, interpret=interpret)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        return paged_decode_attention_kernel(
            q, k_pool, v_pool, block_tables, lengths, interpret=interpret)
    return paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths)
