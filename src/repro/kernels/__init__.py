"""Pallas TPU kernels for the PLAM simulator's compute hot-spots."""
from .decode_attention import (  # noqa: F401
    decode_attention,
    decode_attention_ref,
    gather_pages,
    paged_decode_attention,
    paged_decode_attention_ref,
)
from .ops import (  # noqa: F401
    plam_dense,
    plam_matmul_bits,
    posit_decode,
    posit_encode,
    posit_quantize,
)
