"""Pallas TPU kernels for the PLAM simulator's compute hot-spots."""
from .ops import (  # noqa: F401
    plam_dense,
    plam_matmul_bits,
    posit_decode,
    posit_encode,
    posit_quantize,
)
