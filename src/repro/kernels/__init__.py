"""Pallas TPU kernels for the PLAM simulator's compute hot-spots."""
from .decode_attention import (  # noqa: F401
    decode_attention,
    decode_attention_ref,
    gather_pages,
    paged_decode_attention,
    paged_decode_attention_ref,
)
from .ops import (  # noqa: F401
    exact_mul_elementwise,
    plam_dense,
    plam_matmul_bits,
    plam_mul_elementwise,
    posit_decode,
    posit_encode,
    posit_quantize,
)
