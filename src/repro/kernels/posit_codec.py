"""Pallas TPU kernels for posit encode/decode (quantization hot path).

Pure element-wise bit manipulation — memory-bound by design.  The kernel
bodies reuse the exact jnp bit kernels from ``repro.numerics.posit`` so
there is a single source of truth for the codec; Pallas simply stages
them over VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.numerics import PositSpec
from repro.numerics.plam import exact_mul as _exact_mul
from repro.numerics.plam import plam_mul as _plam_mul
from repro.numerics.posit import decode as _decode
from repro.numerics.posit import encode as _encode

DEFAULT_BLOCK = (256, 256)


def _encode_kernel(x_ref, o_ref, *, spec: PositSpec):
    o_ref[...] = _encode(x_ref[...], spec)


def _decode_kernel(b_ref, o_ref, *, spec: PositSpec):
    o_ref[...] = _decode(b_ref[...], spec)


def _quantize_kernel(x_ref, o_ref, *, spec: PositSpec):
    o_ref[...] = _decode(_encode(x_ref[...], spec), spec)


def _plam_mul_kernel(a_ref, b_ref, o_ref, *, spec: PositSpec):
    o_ref[...] = _plam_mul(a_ref[...], b_ref[...], spec)


def _exact_mul_kernel(a_ref, b_ref, o_ref, *, spec: PositSpec):
    o_ref[...] = _exact_mul(a_ref[...], b_ref[...], spec)


def _tiled_elementwise(kernel, x, out_dtype, spec, block, interpret):
    """Run an element-wise kernel over a 2D-tiled view of x."""
    shape = x.shape
    flat = x.reshape(-1)
    total = flat.shape[0]
    bcols = block[0] * block[1]
    pad = (-total) % bcols
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.shape[0] // block[1]
    x2 = flat.reshape(rows, block[1])
    grid = (rows // block[0],)
    out = pl.pallas_call(
        functools.partial(kernel, spec=spec),
        grid=grid,
        in_specs=[pl.BlockSpec((block[0], block[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block[0], block[1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block[1]), out_dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(-1)[:total].reshape(shape)


def _tiled_elementwise2(kernel, a, b, out_dtype, spec, block, interpret):
    """Run a two-input element-wise kernel over 2D-tiled views of a, b."""
    assert a.shape == b.shape, (a.shape, b.shape)
    shape = a.shape
    fa = a.reshape(-1)
    fb = b.reshape(-1)
    total = fa.shape[0]
    bcols = block[0] * block[1]
    pad = (-total) % bcols
    if pad:
        fa = jnp.pad(fa, (0, pad))
        fb = jnp.pad(fb, (0, pad))
    rows = fa.shape[0] // block[1]
    a2 = fa.reshape(rows, block[1])
    b2 = fb.reshape(rows, block[1])
    grid = (rows // block[0],)
    spec2 = pl.BlockSpec((block[0], block[1]), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(kernel, spec=spec),
        grid=grid,
        in_specs=[spec2, spec2],
        out_specs=spec2,
        out_shape=jax.ShapeDtypeStruct((rows, block[1]), out_dtype),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:total].reshape(shape)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def posit_encode(x, spec: PositSpec = PositSpec(16, 1), *, block=DEFAULT_BLOCK, interpret=False):
    return _tiled_elementwise(_encode_kernel, x.astype(jnp.float32), jnp.int32, spec, block, interpret)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def posit_decode(bits, spec: PositSpec = PositSpec(16, 1), *, block=DEFAULT_BLOCK, interpret=False):
    return _tiled_elementwise(_decode_kernel, bits, jnp.float32, spec, block, interpret)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def posit_quantize(x, spec: PositSpec = PositSpec(16, 1), *, block=DEFAULT_BLOCK, interpret=False):
    return _tiled_elementwise(_quantize_kernel, x.astype(jnp.float32), jnp.float32, spec, block, interpret)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def plam_mul_elementwise(a_bits, b_bits, spec: PositSpec = PositSpec(16, 1), *, block=DEFAULT_BLOCK, interpret=False):
    """Element-wise PLAM pattern product staged over VMEM tiles."""
    return _tiled_elementwise2(
        _plam_mul_kernel, a_bits, b_bits, jnp.int32, spec, block, interpret
    )


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def exact_mul_elementwise(a_bits, b_bits, spec: PositSpec = PositSpec(16, 1), *, block=DEFAULT_BLOCK, interpret=False):
    """Element-wise exact posit pattern product (n <= 16) over VMEM tiles."""
    return _tiled_elementwise2(
        _exact_mul_kernel, a_bits, b_bits, jnp.int32, spec, block, interpret
    )
