"""mamba2-780m: SSD, attention-free [arXiv:2405.21060]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv=0,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
        sub_quadratic=True,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16",
    )
