"""zamba2-1.2b: Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv=32,
        d_ff=8192, vocab=32000, act="gelu", glu=True,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
        shared_attn_every=6, sub_quadratic=True,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16",
    )
