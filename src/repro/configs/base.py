"""Model / run configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.modes import NumericsConfig
from repro.core.policy import NumericsPolicy, parse_policy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    # transformer backbone
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 512
    act: str = "silu"  # silu | gelu
    glu: bool = True  # gated MLP (SwiGLU/GeGLU)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma-style sqrt(d) embedding scale
    attn_logit_softcap: Optional[float] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    capacity_factor: float = 1.25
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): shared attention block applied every k ssm blocks
    shared_attn_every: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    frontend: Optional[str] = None  # 'audio' | 'vision' stub frontends
    frontend_dim: int = 0  # dim of precomputed frame/patch embeddings
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    # numerics + dtypes: a uniform NumericsConfig or a per-site
    # NumericsPolicy (see repro.core.policy for the role taxonomy)
    numerics: Union[NumericsConfig, NumericsPolicy] = NumericsConfig(mode="bf16")
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    # misc
    sub_quadratic: bool = False  # supports 500k-context decode
    remat: bool = False
    kv_seq_tp: bool = False  # decode: shard KV-cache seq over TP axis
    moe_groups: int = 1  # MoE dispatch groups (set = data-parallel degree)
    expert_parallel: bool = False  # shard experts over the model axis (EP)
    flash_block: int = 0  # blockwise (flash) attention KV block; 0 = reference path

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def with_numerics(self, ncfg) -> "ModelConfig":
        """ncfg: NumericsConfig, NumericsPolicy, or a policy string /
        dict (parsed via repro.core.policy.parse_policy)."""
        if not isinstance(ncfg, (NumericsConfig, NumericsPolicy)):
            ncfg = parse_policy(ncfg)
        return dataclasses.replace(self, numerics=ncfg)

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale version of the same family."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_dim=128 if self.frontend else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg: ModelConfig):
    """Which of the four assigned shapes apply to this architecture."""
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # quadratic attention at 524k: skipped (DESIGN.md §5)
        out.append(s)
    return out
