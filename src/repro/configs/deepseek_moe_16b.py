"""deepseek-moe-16b: fine-grained 64 routed top-6 + 2 shared [arXiv:2401.06066]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=102400, act="silu", glu=True,
        n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
