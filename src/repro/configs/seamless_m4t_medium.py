"""seamless-m4t-medium backbone: enc-dec, audio stub frontend [arXiv:2308.11596]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=24, enc_layers=12, dec_layers=12,
        d_model=1024, n_heads=16, n_kv=16, head_dim=64,
        d_ff=4096, vocab=256206, act="gelu", glu=False,
        frontend="audio", frontend_dim=160,  # stacked mel-frame embeddings
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16",
    )
