"""granite-3.0-1b-a400m: 32 experts, top-8 [hf:ibm-granite]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv=8, head_dim=64,
        d_ff=512, vocab=49155, act="silu", glu=True,
        n_experts=32, top_k=8, moe_d_ff=512, n_shared_experts=0,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16",
    )
