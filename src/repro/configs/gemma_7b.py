"""gemma-7b: GeGLU, head_dim 256, MHA (kv=16) [arXiv:2403.08295]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv=16, head_dim=256,
        d_ff=24576, vocab=256000, act="gelu", glu=True,  # GeGLU
        tie_embeddings=True, scale_embeddings=True,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
