"""command-r-plus-104b: GQA, no-bias, tied embeddings [hf:CohereForAI]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv=8, head_dim=128,
        d_ff=33792, vocab=256000, act="silu", glu=True,
        tie_embeddings=True, rope_theta=8_000_000.0,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
