"""Architecture configs: the 10 assigned + the paper's own models."""
from .base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    ModelConfig,
    PREFILL_32K,
    ShapeSpec,
    TRAIN_4K,
    applicable_shapes,
    shape_by_name,
)

from . import (  # noqa: F401
    command_r_plus_104b,
    deepseek_moe_16b,
    gemma_7b,
    granite_moe_1b_a400m,
    mamba2_780m,
    minitron_8b,
    qwen2_vl_72b,
    seamless_m4t_medium,
    yi_6b,
    zamba2_1_2b,
)

ARCHS = {
    "minitron-8b": minitron_8b.config,
    "yi-6b": yi_6b.config,
    "command-r-plus-104b": command_r_plus_104b.config,
    "gemma-7b": gemma_7b.config,
    "mamba2-780m": mamba2_780m.config,
    "seamless-m4t-medium": seamless_m4t_medium.config,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.config,
    "deepseek-moe-16b": deepseek_moe_16b.config,
    "qwen2-vl-72b": qwen2_vl_72b.config,
    "zamba2-1.2b": zamba2_1_2b.config,
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]()
