"""qwen2-vl-72b backbone: M-RoPE, stub vision frontend [arXiv:2409.12191]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=29568, vocab=152064, act="silu", glu=True,
        rope_theta=1_000_000.0,
        frontend="vision", frontend_dim=8192,
        mrope_sections=(16, 24, 24),  # half-dims (t, h, w)
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
