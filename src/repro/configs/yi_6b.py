"""yi-6b: llama-architecture GQA [arXiv:2403.04652]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv=4, head_dim=128,
        d_ff=11008, vocab=64000, act="silu", glu=True,
        rope_theta=5_000_000.0,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
