"""minitron-8b: width-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.core.modes import NumericsConfig
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=16384, vocab=256000, act="relu2", glu=False,  # squared-ReLU MLP
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    )
