"""The paper's own DNNs (Table I): 2-hidden-layer MLPs, LeNet-5, CifarNet.

Every multiplication routes through the numerics-aware dense layer —
convolutions are lowered to im2col + nmatmul, so PLAM applies to them
exactly as the paper's SoftPosit-based emulation does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense_init
from repro.core.modes import NumericsConfig, nmatmul


def _conv2d(x, w, ncfg: NumericsConfig, stride=1):
    """x: [B,H,W,C]; w: [kh,kw,C,F] via im2col + numerics-aware matmul."""
    kh, kw, c, f = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H', W', kh*kw*C]
    b, ho, wo, _ = patches.shape
    out = nmatmul(patches.reshape(b * ho * wo, -1), w.reshape(-1, f), ncfg,
                  out_dtype=x.dtype)
    return out.reshape(b, ho, wo, f)


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


# ---------------------------------------------------------------------------
# MLPs (ISOLET / UCI-HAR rows of Table I)
# ---------------------------------------------------------------------------

def mlp_init(key, dims):
    """dims e.g. (617, 128, 64, 26)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(k, dims[i], dims[i + 1])
        for i, k in enumerate(keys)
    } | {f"b{i}": jnp.zeros((dims[i + 1],), jnp.float32) for i in range(len(dims) - 1)}


def mlp_apply(params, x, ncfg: NumericsConfig):
    n = sum(1 for k in params if k.startswith("w"))
    h = x
    for i in range(n):
        h = nmatmul(h, params[f"w{i}"], ncfg, out_dtype=jnp.float32) + params[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h  # logits


# ---------------------------------------------------------------------------
# LeNet-5 (MNIST / SVHN rows)
# ---------------------------------------------------------------------------

def lenet5_init(key, in_ch=1, n_classes=10, hw=28):
    k = jax.random.split(key, 5)
    flat = (hw // 4) * (hw // 4) * 16
    return {
        "c1": dense_init(k[0], 5 * 5 * in_ch, 6).reshape(5, 5, in_ch, 6),
        "c2": dense_init(k[1], 5 * 5 * 6, 16).reshape(5, 5, 6, 16),
        "f1": dense_init(k[2], flat, 120), "b1": jnp.zeros((120,)),
        "f2": dense_init(k[3], 120, 84), "b2": jnp.zeros((84,)),
        "f3": dense_init(k[4], 84, n_classes), "b3": jnp.zeros((n_classes,)),
    }


def lenet5_apply(params, x, ncfg: NumericsConfig):
    h = jax.nn.relu(_conv2d(x, params["c1"], ncfg))
    h = _maxpool(h)
    h = jax.nn.relu(_conv2d(h, params["c2"], ncfg))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(nmatmul(h, params["f1"], ncfg, out_dtype=jnp.float32) + params["b1"])
    h = jax.nn.relu(nmatmul(h, params["f2"], ncfg, out_dtype=jnp.float32) + params["b2"])
    return nmatmul(h, params["f3"], ncfg, out_dtype=jnp.float32) + params["b3"]


# ---------------------------------------------------------------------------
# CifarNet (CIFAR-10 row)
# ---------------------------------------------------------------------------

def cifarnet_init(key, in_ch=3, n_classes=10, hw=32):
    k = jax.random.split(key, 4)
    flat = (hw // 4) * (hw // 4) * 64
    return {
        "c1": dense_init(k[0], 5 * 5 * in_ch, 32).reshape(5, 5, in_ch, 32),
        "c2": dense_init(k[1], 5 * 5 * 32, 64).reshape(5, 5, 32, 64),
        "f1": dense_init(k[2], flat, 384), "b1": jnp.zeros((384,)),
        "f2": dense_init(k[3], 384, n_classes), "b2": jnp.zeros((n_classes,)),
    }


def cifarnet_apply(params, x, ncfg: NumericsConfig):
    h = jax.nn.relu(_conv2d(x, params["c1"], ncfg))
    h = _maxpool(h)
    h = jax.nn.relu(_conv2d(h, params["c2"], ncfg))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(nmatmul(h, params["f1"], ncfg, out_dtype=jnp.float32) + params["b1"])
    return nmatmul(h, params["f2"], ncfg, out_dtype=jnp.float32) + params["b2"]


# ---------------------------------------------------------------------------
# training / eval harness
# ---------------------------------------------------------------------------

def xent(logits, y):
    return jnp.mean(jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])


def train_classifier(init_fn, apply_fn, x, y, *, epochs=10, batch=128, lr=1e-3, seed=0,
                     ncfg=NumericsConfig(mode="f32")):
    """Adam training in the given numerics mode (paper trains posit16
    models directly in posit arithmetic)."""
    params = init_fn(jax.random.PRNGKey(seed))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t, xb, yb):
        loss, g = jax.value_and_grad(lambda p: xent(apply_fn(p, xb, ncfg), yb))(params)
        m = jax.tree.map(lambda m_, g_: 0.9 * m_ + 0.1 * g_, m, g)
        v = jax.tree.map(lambda v_, g_: 0.999 * v_ + 0.001 * g_ * g_, v, g)
        mb = jax.tree.map(lambda m_: m_ / (1 - 0.9 ** t), m)
        vb = jax.tree.map(lambda v_: v_ / (1 - 0.999 ** t), v)
        params = jax.tree.map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + 1e-8), params, mb, vb)
        return params, m, v, loss

    n = x.shape[0]
    rng = jax.random.PRNGKey(seed + 1)
    t = 0
    for ep in range(epochs):
        rng, k = jax.random.split(rng)
        order = jax.random.permutation(k, n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            t += 1
            params, m, v, loss = step(params, m, v, jnp.float32(t), x[idx], y[idx])
    return params


def accuracy(apply_fn, params, x, y, ncfg: NumericsConfig, batch=512, topk=(1,)):
    correct = {k: 0 for k in topk}
    n = x.shape[0]
    fn = jax.jit(lambda xb: apply_fn(params, xb, ncfg))
    for i in range(0, n, batch):
        logits = fn(x[i:i + batch])
        yb = y[i:i + batch]
        rank = jnp.argsort(-logits, axis=-1)
        for k in topk:
            correct[k] += int(jnp.sum(jnp.any(rank[:, :k] == yb[:, None], axis=1)))
    return {k: c / n for k, c in correct.items()}
