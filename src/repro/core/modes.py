"""Numerics modes: how every matmul in the framework multiplies.

This is the integration point of the paper's technique.  Each model
config carries a :class:`NumericsConfig`; `nmatmul` dispatches:

* ``f32`` / ``bf16``      — exact MXU matmul (baselines).
* ``posit_quant``         — operands projected onto the Posit<n,es>
  grid (STE gradients), exact multiply.  The scalable emulation of
  posit *training* (Table II's exact-posit column).
* ``plam_sim``            — bit-exact PLAM: every scalar product is the
  paper's logarithm-approximate multiplication, antilogged to linear
  f32 and accumulated (EMAC).  K-chunked jnp; lowers under pjit for the
  distributed dry-run.  The Pallas kernel (`repro.kernels`) is the same
  math tiled for VMEM and is used on real TPU / in benchmarks.
* ``mitchell_f32``        — float-domain Mitchell (Cheng et al. [20]),
  the floating-point counterpart the paper compares against.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.numerics import (
    PositSpec,
    decode,
    encode,
    plam_product_f32,
    quantize,
    unpack16,
)
from repro.numerics.plam import mitchell_mul_f32

MODES = ("f32", "bf16", "posit_quant", "plam_sim", "mitchell_f32")


@dataclasses.dataclass(frozen=True)
class NumericsConfig:
    mode: str = "bf16"
    n: int = 16
    es: int = 1
    quantize_acts: bool = True  # posit-quantize activations too (not just weights)
    plam_chunk: int = 64  # K-chunk for the jnp plam_sim path
    # Weights already sit on the posit grid (quantized at load / in the
    # optimizer update), so the per-matmul weight codec is skipped.
    # Value-identical to quantize-on-read; removes the dominant VPU +
    # HBM cost of the simulation (see EXPERIMENTS.md §Perf).
    prequantized_weights: bool = False
    # Carrier dtype for quantized matmuls: "f32" preserves the posit
    # grid exactly; "bf16" re-rounds to bf16 (double quantization) but
    # runs on the MXU with half the traffic — the beyond-paper mode.
    carrier: str = "f32"

    def __post_init__(self):
        assert self.mode in MODES, self.mode

    @property
    def spec(self) -> PositSpec:
        return PositSpec(self.n, self.es)


EXACT_BF16 = NumericsConfig(mode="bf16")
POSIT16_QUANT = NumericsConfig(mode="posit_quant", n=16, es=1)
PLAM16 = NumericsConfig(mode="plam_sim", n=16, es=1)


@partial(jax.custom_jvp, nondiff_argnums=(1,))
def _quantize_bf16(x, spec):
    """Posit-grid projection with a bf16 STE boundary.

    The straight-through identity lives at the *bf16* input dtype, so
    reverse-mode cotangents (and the TP all-reduces that carry them)
    stay bf16 instead of round-tripping through the f32 codec segment.
    """
    return quantize(x.astype(jnp.float32), spec).astype(jnp.bfloat16)


@_quantize_bf16.defjvp
def _quantize_bf16_jvp(spec, primals, tangents):
    (x,), (dx,) = primals, tangents
    return _quantize_bf16(x, spec), dx.astype(jnp.bfloat16)


def _plam_matmul_jnp(x, w, spec: PositSpec, chunk: int):
    """Bit-exact PLAM matmul in pure jnp, K-chunked.

    x: [..., K] f32-ish, w: [K, N].  Every pairwise product is the
    paper's approximate multiplication; accumulation is linear f32.
    """
    xb = encode(x, spec)
    wb = encode(w, spec)
    k = x.shape[-1]
    n = w.shape[-1]
    lead = x.shape[:-1]
    xb2 = xb.reshape(-1, k)
    m = xb2.shape[0]
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:  # posit pattern 0 is exact zero: padding is value-preserving
        xb2 = jnp.pad(xb2, ((0, 0), (0, pad)))
        wb = jnp.pad(wb, ((0, pad), (0, 0)))
    kc = xb2.shape[1] // chunk
    xb3 = xb2.reshape(m, kc, chunk).transpose(1, 0, 2)  # [kc, M, chunk]
    wb3 = wb.reshape(kc, chunk, n)  # [kc, chunk, N]

    def body(acc, operands):
        xc, wc = operands  # [M, chunk], [chunk, N]
        prods = plam_product_f32(xc[:, :, None], wc[None, :, :], spec)
        return acc + jnp.sum(prods, axis=1, dtype=jnp.float32), None

    acc0 = jnp.zeros((m, n), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (xb3, wb3))
    return acc.reshape(*lead, n)


def _mitchell_matmul_jnp(x, w, chunk: int):
    """Float-domain Mitchell matmul (reference baseline), K-chunked."""
    k = x.shape[-1]
    n = w.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    m = x2.shape[0]
    chunk = min(chunk, k)
    pad = (-k) % chunk
    if pad:
        x2 = jnp.pad(x2, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
    kc = x2.shape[1] // chunk
    x3 = x2.reshape(m, kc, chunk).transpose(1, 0, 2)
    w3 = w.astype(jnp.float32).reshape(kc, chunk, n)

    def body(acc, operands):
        xc, wc = operands
        prods = mitchell_mul_f32(xc[:, :, None], wc[None, :, :])
        return acc + jnp.sum(prods, axis=1, dtype=jnp.float32), None

    acc, _ = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), (x3, w3))
    return acc.reshape(*lead, n)


def _pattern_matmul(x, w_pat, ncfg: NumericsConfig, out_dtype):
    """x @ w where w arrived as pre-encoded posit patterns.

    Prequantized storage (``core.prequant.quantize_params``) carries
    policy-selected weights as int16/int32 posit patterns.  For
    ``plam_sim`` the patterns feed ``kernels.ops.plam_dense`` directly
    — the deployment layout for posit inference (activations encoded on
    the fly, weights never re-encoded).  Every other mode decodes the
    patterns back to their exact posit-grid f32 values and reuses the
    linear-weight path with the per-matmul weight codec skipped
    (``prequantized_weights=True``), which is value-identical to
    quantize-on-read.
    """
    spec = ncfg.spec
    bits = unpack16(w_pat) if w_pat.dtype == jnp.int16 else w_pat.astype(jnp.int32)
    if ncfg.mode == "plam_sim":
        from repro.kernels.ops import plam_dense  # deferred: pulls in pallas

        out = plam_dense(x.astype(jnp.float32), bits, spec)
        return out.astype(out_dtype)
    w_lin = decode(bits, spec)
    ncfg_pq = dataclasses.replace(ncfg, prequantized_weights=True)
    return nmatmul(x, w_lin, ncfg_pq, out_dtype=out_dtype)


def nmatmul(x, w, ncfg: NumericsConfig, out_dtype=None):
    """Numerics-aware x @ w; x: [..., K], w: [K, N].

    Integer-dtype ``w`` is interpreted as pre-encoded Posit<n,es>
    patterns (prequantized weight storage) and dispatched through
    :func:`_pattern_matmul`.
    """
    out_dtype = out_dtype or x.dtype
    if jnp.issubdtype(w.dtype, jnp.integer):
        return _pattern_matmul(x, w, ncfg, out_dtype)
    if ncfg.mode == "f32":
        out = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    elif ncfg.mode == "bf16":
        out = jnp.matmul(
            x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    elif ncfg.mode == "posit_quant":
        spec = ncfg.spec
        if ncfg.carrier == "bf16":
            # bf16 end to end: bf16 STE boundary (cotangents + their TP
            # all-reduces stay bf16), bf16 dot output (row-parallel
            # partial-sum all-reduce in bf16); MXU accumulates f32.
            xq = (
                _quantize_bf16(x, spec)
                if ncfg.quantize_acts
                else x.astype(jnp.bfloat16)
            )
            wq = (
                w.astype(jnp.bfloat16)
                if ncfg.prequantized_weights
                else _quantize_bf16(w, spec)
            )
            out = jnp.matmul(xq, wq)
        else:
            xq = (
                quantize(x.astype(jnp.float32), spec)
                if ncfg.quantize_acts
                else x.astype(jnp.float32)
            )
            wq = (
                w.astype(jnp.float32)
                if ncfg.prequantized_weights
                else quantize(w.astype(jnp.float32), spec)
            )
            out = jnp.matmul(xq, wq)
    elif ncfg.mode == "plam_sim":
        out = _plam_matmul_jnp(
            x.astype(jnp.float32), w.astype(jnp.float32), ncfg.spec, ncfg.plam_chunk
        )
    elif ncfg.mode == "mitchell_f32":
        out = _mitchell_matmul_jnp(x, w, ncfg.plam_chunk)
    else:  # pragma: no cover
        raise ValueError(ncfg.mode)
    return out.astype(out_dtype)


def nquant_weight(w, ncfg: NumericsConfig):
    """Posit-quantize a weight for storage/serving, when the mode asks."""
    if ncfg.mode in ("posit_quant", "plam_sim"):
        return quantize(w.astype(jnp.float32), ncfg.spec).astype(w.dtype)
    return w
