"""Per-site mixed-numerics policy: which multiplier runs at which matmul.

Sensitivity to approximate multiplication is not uniform across a
network — Deep Positron and Fixed-Posit both show the right posit /
fixed format differs per layer and per tensor role.  A
:class:`NumericsPolicy` maps a matmul *site* (a dotted role tag plus an
optional layer index) to a per-site :class:`NumericsConfig`, so one
model can run PLAM MLPs, exact-posit attention and an f32 router at the
same time.

Role taxonomy (see docs/numerics.md for the full table)::

    attn.qkv   attn.out          self-attention projections
    attn.cross.qkv  attn.cross.out   enc-dec cross-attention
    mlp.up  mlp.gate  mlp.down   dense FFN
    moe.router                    MoE gate (f32 baseline rule)
    moe.expert.{up,gate,down}     routed expert FFNs
    moe.shared.{up,gate,down}     DeepSeek-style shared experts
    ssm.proj.in  ssm.proj.out     Mamba2 projections
    lm_head  frontend  hybrid.proj

Policy strings are comma-separated ``selector=cfg`` items::

    default=plam_sim:16:1, moe.router=f32, layers[0,-1]=posit_quant

* ``selector`` is ``default`` (every site), a role or role group
  (``attn`` matches ``attn.qkv`` and ``attn.out``), ``layers[SPEC]``
  (every role at the selected layers), or ``role@layers[SPEC]``.
  ``SPEC`` is a comma list of indices and python-style ``a:b`` ranges;
  negative indices count from the end.
* ``cfg`` is ``mode[:n[:es]]`` — e.g. ``plam_sim:16:1``, ``f32``.

Resolution: among matching rules the most *role-specific* wins
(exact role > role group > layers-only > default); a layer selector
breaks ties at equal role depth; later rules win exact ties.  The
legacy hard-coded "router stays exact f32" escape hatch survives as an
implicit ``moe.router=f32`` rule that any explicit ``moe.router=...``
overrides.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import List, Optional, Tuple, Union

from .modes import MODES, NumericsConfig

__all__ = [
    "NumericsPolicy",
    "Rule",
    "BoundPolicy",
    "as_policy",
    "bind",
    "cfg_spec_str",
    "describe",
    "layer_segments",
    "load_policy_arg",
    "parse_cfg_spec",
    "parse_policy",
    "parse_policy_str",
    "policy_from_dict",
    "policy_to_dict",
    "policy_to_str",
    "site",
    "site_for",
]

# A layer-selector item: ("idx", i, None) or ("range", start, stop) with
# python range semantics; start/stop may be None (open end) and
# negative indices count from n_layers.
LayerItem = Tuple[str, Optional[int], Optional[int]]

_CFG_FIELDS = {f.name for f in dataclasses.fields(NumericsConfig)}


def _norm(i: int, n_layers: int) -> int:
    return i + n_layers if i < 0 else i


def _item_matches(item: LayerItem, layer: int, n_layers: int) -> bool:
    kind, a, b = item
    if kind == "idx":
        return layer == _norm(a, n_layers)
    lo = 0 if a is None else _norm(a, n_layers)
    hi = n_layers if b is None else _norm(b, n_layers)
    return lo <= layer < hi


@dataclasses.dataclass(frozen=True)
class Rule:
    """One policy entry: (role pattern, layer selector) -> config.

    ``role == ""`` matches every role; otherwise the rule matches the
    exact role and every dotted descendant (``"mlp"`` covers
    ``"mlp.up"``).  ``layers is None`` matches every layer, including
    sites with no layer index at all; a concrete selector only matches
    when the call site knows its layer.
    """

    role: str = ""
    layers: Optional[Tuple[LayerItem, ...]] = None
    cfg: NumericsConfig = NumericsConfig()

    def matches(
        self, role: str, layer: Optional[int], n_layers: Optional[int]
    ) -> bool:
        if self.role and role != self.role and not role.startswith(self.role + "."):
            return False
        if self.layers is not None:
            if layer is None or n_layers is None:
                return False
            if not any(_item_matches(it, layer, n_layers) for it in self.layers):
                return False
        return True

    @property
    def role_depth(self) -> int:
        return 0 if not self.role else self.role.count(".") + 1


# The pre-refactor code hard-wired an exact-f32 router inside moe.py
# (routing is control flow).  That escape hatch survives as the lowest-
# priority *exact* rule: any explicit ``moe.router=...`` overrides it,
# but a bare ``default=plam_sim`` does not silently approximate routing.
_ROUTER_BASELINE = Rule(role="moe.router", cfg=NumericsConfig(mode="f32"))


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """An ordered rule list resolving matmul sites to NumericsConfigs."""

    rules: Tuple[Rule, ...] = ()

    @staticmethod
    def uniform(cfg: NumericsConfig) -> "NumericsPolicy":
        return NumericsPolicy(rules=(Rule(cfg=cfg),))

    def resolve(
        self,
        role: str,
        layer: Optional[int] = None,
        n_layers: Optional[int] = None,
    ) -> NumericsConfig:
        """Most-specific matching rule's config for one site.

        Precedence key: (role depth, has-layer-selector, rule order) —
        maximal wins.  The implicit router baseline sits at order -1 so
        explicit rules of equal specificity beat it.
        """
        best: Optional[NumericsConfig] = None
        best_key = None
        for i, rule in enumerate((_ROUTER_BASELINE, *self.rules)):
            if not rule.matches(role, layer, n_layers):
                continue
            key = (rule.role_depth, 0 if rule.layers is None else 1, i)
            if best_key is None or key >= best_key:
                best, best_key = rule.cfg, key
        if best is None:
            raise KeyError(
                f"numerics policy has no rule for site {role!r}; "
                "add a 'default=<mode>' rule"
            )
        return best

    def has_layer_rules(self) -> bool:
        return any(r.layers is not None for r in self.rules)


@dataclasses.dataclass(frozen=True)
class BoundPolicy:
    """A policy fixed to one layer context; what model blocks receive."""

    policy: NumericsPolicy
    layer: Optional[int] = None
    n_layers: Optional[int] = None

    def site(self, role: str) -> NumericsConfig:
        return self.policy.resolve(role, self.layer, self.n_layers)


# Uniform legacy configs keep the router baseline too, so a plain
# ``NumericsConfig(mode="plam_sim")`` stays bit-identical to the
# pre-policy code (which special-cased the router inline).
_UNIFORM_BASELINE = {"moe.router": NumericsConfig(mode="f32")}

SiteNumerics = Union[NumericsConfig, BoundPolicy]


def site(nc: SiteNumerics, role: str) -> NumericsConfig:
    """Resolve the config for one matmul site.

    ``nc`` is whatever flowed down from ``ModelConfig.numerics``: a
    plain :class:`NumericsConfig` (uniform numerics, the legacy path)
    or a :class:`BoundPolicy` produced by :func:`bind`.
    """
    if isinstance(nc, NumericsConfig):
        return _UNIFORM_BASELINE.get(role, nc)
    return nc.site(role)


def bind(
    numerics,
    layer: Optional[int] = None,
    n_layers: Optional[int] = None,
) -> SiteNumerics:
    """Fix a config-or-policy to a layer context for use with site()."""
    if isinstance(numerics, NumericsConfig):
        return numerics
    return BoundPolicy(as_policy(numerics), layer, n_layers)


def site_for(
    numerics,
    role: str,
    layer: Optional[int] = None,
    n_layers: Optional[int] = None,
) -> NumericsConfig:
    """One-shot ``site(bind(numerics, ...), role)``."""
    return site(bind(numerics, layer, n_layers), role)


def layer_segments(
    numerics,
    n_layers: int,
    start: int = 0,
    size: Optional[int] = None,
) -> List[Tuple[int, int, SiteNumerics]]:
    """Split a scanned layer stack into policy-uniform segments.

    Layer-range rules make the per-site config a function of the layer
    index, which a single ``lax.scan`` cannot express (every scanned
    layer shares one trace).  This helper splits the absolute layer
    range ``[start, start + size)`` into maximal runs matching the same
    rule subset; each run scans with one bound policy.  Uniform
    policies return a single segment — the exact pre-refactor scan.

    Returns ``[(rel_start, run_len, bound_numerics)]`` with
    ``rel_start`` relative to the sliced stack.
    """
    size = n_layers if size is None else size
    if isinstance(numerics, NumericsConfig):
        return [(0, size, numerics)]
    policy = as_policy(numerics)
    layered = [r for r in policy.rules if r.layers is not None]
    if not layered:
        return [(0, size, BoundPolicy(policy, None, n_layers))]

    def signature(layer: int):
        return tuple(
            any(_item_matches(it, layer, n_layers) for it in r.layers)
            for r in layered
        )

    segments: List[Tuple[int, int, SiteNumerics]] = []
    seg_start = 0
    seg_sig = signature(start)
    for rel in range(1, size):
        sig = signature(start + rel)
        if sig != seg_sig:
            bound = BoundPolicy(policy, start + seg_start, n_layers)
            segments.append((seg_start, rel - seg_start, bound))
            seg_start, seg_sig = rel, sig
    bound = BoundPolicy(policy, start + seg_start, n_layers)
    segments.append((seg_start, size - seg_start, bound))
    return segments


# ---------------------------------------------------------------------------
# parsing / serialization
# ---------------------------------------------------------------------------


def _parse_layer_spec(spec: str) -> Tuple[LayerItem, ...]:
    """``"0,-1,2:4,:3"`` -> layer items."""
    items: List[LayerItem] = []
    for raw in spec.split(","):
        tok = raw.strip()
        if not tok:
            raise ValueError(f"empty layer item in layers[{spec}]")
        if ":" in tok:
            a_s, b_s = tok.split(":", 1)
            a = int(a_s) if a_s.strip() else None
            b = int(b_s) if b_s.strip() else None
            items.append(("range", a, b))
        else:
            items.append(("idx", int(tok), None))
    return tuple(items)


def _layer_spec_str(items: Tuple[LayerItem, ...]) -> str:
    parts = []
    for kind, a, b in items:
        if kind == "idx":
            parts.append(str(a))
        else:
            parts.append(f"{'' if a is None else a}:{'' if b is None else b}")
    return ",".join(parts)


_LAYERS_RE = re.compile(r"^layers\[(?P<spec>[^\]]*)\]$")


def _parse_selector(sel: str) -> Tuple[str, Optional[Tuple[LayerItem, ...]]]:
    sel = sel.strip()
    role, layers_part = sel, None
    if "@" in sel:
        role, layers_part = (p.strip() for p in sel.split("@", 1))
    elif sel.startswith("layers["):
        role, layers_part = "", sel
    if role == "default":
        role = ""
    layers = None
    if layers_part is not None:
        m = _LAYERS_RE.match(layers_part)
        if not m:
            raise ValueError(f"bad layer selector in {sel!r}")
        layers = _parse_layer_spec(m.group("spec"))
    if role and not re.fullmatch(r"[A-Za-z_][\w.]*", role):
        raise ValueError(f"bad role {role!r} in selector {sel!r}")
    return role, layers


def _selector_str(role: str, layers: Optional[Tuple[LayerItem, ...]]) -> str:
    if layers is None:
        return role or "default"
    spec = f"layers[{_layer_spec_str(layers)}]"
    return f"{role}@{spec}" if role else spec


def parse_cfg_spec(spec) -> NumericsConfig:
    """``"plam_sim:16:1"`` / ``"f32"`` / field dict -> NumericsConfig."""
    if isinstance(spec, NumericsConfig):
        return spec
    if isinstance(spec, dict):
        unknown = set(spec) - _CFG_FIELDS
        if unknown:
            raise ValueError(f"unknown NumericsConfig fields {sorted(unknown)}")
        return NumericsConfig(**spec)
    parts = [p.strip() for p in str(spec).split(":")]
    if parts[0] not in MODES:
        raise ValueError(f"unknown numerics mode {parts[0]!r}; pick from {MODES}")
    kw = {"mode": parts[0]}
    if len(parts) > 1 and parts[1]:
        kw["n"] = int(parts[1])
    if len(parts) > 2 and parts[2]:
        kw["es"] = int(parts[2])
    if len(parts) > 3:
        raise ValueError(f"bad numerics spec {spec!r} (want mode[:n[:es]])")
    return NumericsConfig(**kw)


def cfg_spec_str(cfg: NumericsConfig) -> str:
    """Compact mode[:n[:es]] form of one config (inverse of parse_cfg_spec)."""
    if cfg.mode in ("f32", "bf16", "mitchell_f32"):
        return cfg.mode
    return f"{cfg.mode}:{cfg.n}:{cfg.es}"


def _split_top_level(s: str) -> List[str]:
    """Split on commas that are not inside ``layers[...]`` brackets."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "[":
            depth += 1
        elif ch == "]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [p for p in (p.strip() for p in out) if p]


def parse_policy_str(s: str) -> NumericsPolicy:
    """Parse the compact comma syntax; a bare mode spec means uniform."""
    s = s.strip()
    if "=" not in s:
        return NumericsPolicy.uniform(parse_cfg_spec(s))
    rules = []
    for item in _split_top_level(s):
        if "=" not in item:
            raise ValueError(f"policy item {item!r} is not selector=cfg")
        sel, spec = (p.strip() for p in item.split("=", 1))
        role, layers = _parse_selector(sel)
        rules.append(Rule(role=role, layers=layers, cfg=parse_cfg_spec(spec)))
    return NumericsPolicy(rules=tuple(rules))


def parse_policy(x) -> NumericsPolicy:
    """Coerce str / dict / NumericsConfig / NumericsPolicy to a policy."""
    if isinstance(x, NumericsPolicy):
        return x
    if isinstance(x, NumericsConfig):
        return NumericsPolicy.uniform(x)
    if isinstance(x, dict):
        return policy_from_dict(x)
    if isinstance(x, str):
        return parse_policy_str(x)
    raise TypeError(f"cannot build a NumericsPolicy from {type(x).__name__}")


def as_policy(x) -> NumericsPolicy:
    return parse_policy(x)


def policy_to_dict(policy) -> dict:
    """Lossless JSON-safe form: {selector: NumericsConfig fields}.

    Selector strings keep rule order (dicts preserve insertion order),
    and configs serialize field-complete so carrier / quantize_acts /
    prequantized_weights survive checkpoint metadata round trips.
    """
    policy = as_policy(policy)
    out = {}
    for rule in policy.rules:
        out[_selector_str(rule.role, rule.layers)] = dataclasses.asdict(rule.cfg)
    return out


def policy_from_dict(d: dict) -> NumericsPolicy:
    rules = []
    for sel, spec in d.items():
        role, layers = _parse_selector(str(sel))
        rules.append(Rule(role=role, layers=layers, cfg=parse_cfg_spec(spec)))
    return NumericsPolicy(rules=tuple(rules))


def policy_to_str(policy) -> str:
    """Compact round-trippable string (drops non-mode/n/es fields)."""
    policy = as_policy(policy)
    return ", ".join(
        f"{_selector_str(r.role, r.layers)}={cfg_spec_str(r.cfg)}"
        for r in policy.rules
    )


def describe(numerics) -> str:
    """Short human/report label for a config or policy."""
    if isinstance(numerics, NumericsConfig):
        return numerics.mode
    return policy_to_str(numerics)


def load_policy_arg(arg: str) -> NumericsPolicy:
    """CLI helper: ``arg`` is a policy string or a path to a saved
    policy artifact (the JSON written by numerics/calibrate.py, or any
    JSON dict in ``policy_to_dict`` form).  A path-shaped argument
    (.json suffix or a path separator) that does not exist is an error
    — not a policy string — so typo'd artifact paths fail clearly."""
    if os.path.exists(arg):
        with open(arg) as f:
            data = json.load(f)
        if isinstance(data, dict) and "policy" in data:
            data = data["policy"]
        return policy_from_dict(data)
    if arg.endswith(".json") or os.sep in arg:
        raise FileNotFoundError(f"numerics policy artifact not found: {arg!r}")
    return parse_policy_str(arg)
