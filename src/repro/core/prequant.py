"""Prequantized posit weight storage: encode once, serve forever.

``quantize_params`` walks a model's parameter pytree, maps each weight
to its matmul site role, and — where the numerics policy resolves that
site to a posit mode (``posit_quant`` / ``plam_sim``) — replaces the
f32 weight with its Posit<n,es> bit patterns, packed to int16 for
n <= 16.  ``core.modes.nmatmul`` recognizes integer-dtype weights and
consumes them without ever re-encoding: the ``plam_sim`` path feeds
``kernels.ops.plam_dense`` (the deployment layout for posit inference),
exact-posit paths decode to the grid values the per-matmul codec would
have produced, bit-identically.

The pass is inference-only (patterns carry no gradients); training
keeps linear weights and the existing ``prequantized_weights`` flag
semantics.  Quantized pytrees round-trip through
``train.checkpoint.save/restore`` unchanged — the npz stores the int16
leaves and the site metadata rides in the manifest's ``extra`` dict.
"""

from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.numerics import encode, pack16

from .modes import NumericsConfig
from .policy import layer_segments, site_for

# Parameter path -> site role.  Paths are '/'-joined pytree key paths
# (e.g. "layers/attn/wq", "enc_layers/mlp/wu", "shared/out_proj").
# Anything unmatched (embeddings, norms, convs, biases, SSM scalars) is
# left untouched.
_PATH_ROLES: Tuple[Tuple[str, str], ...] = (
    (r"(^|/)xattn/w[qkv]$", "attn.cross.qkv"),
    (r"(^|/)xattn/wo$", "attn.cross.out"),
    (r"(^|/)attn/w[qkv]$", "attn.qkv"),
    (r"(^|/)attn/wo$", "attn.out"),
    (r"(^|/)moe/router$", "moe.router"),
    (r"(^|/)moe/wu$", "moe.expert.up"),
    (r"(^|/)moe/wg$", "moe.expert.gate"),
    (r"(^|/)moe/wd$", "moe.expert.down"),
    (r"(^|/)moe/shared/wu$", "moe.shared.up"),
    (r"(^|/)moe/shared/wg$", "moe.shared.gate"),
    (r"(^|/)moe/shared/wd$", "moe.shared.down"),
    (r"(^|/)mlp/wu$", "mlp.up"),
    (r"(^|/)mlp/wg$", "mlp.gate"),
    (r"(^|/)mlp/wd$", "mlp.down"),
    (r"(^|/)mamba/in_proj$", "ssm.proj.in"),
    (r"(^|/)mamba/out_proj$", "ssm.proj.out"),
    (r"^shared/out_proj$", "hybrid.proj"),
    (r"^frontend_proj$", "frontend"),
    (r"^unembed$", "lm_head"),
)

_POSIT_MODES = ("posit_quant", "plam_sim")


def param_role(path: str) -> Optional[str]:
    """Site role for a '/'-joined parameter path, or None (skip)."""
    for pat, role in _PATH_ROLES:
        if re.search(pat, path):
            return role
    return None


def _path_str(key_path) -> str:
    parts = []
    for p in key_path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _site_cfg_if_uniform(cfg, role: str, layered: bool) -> Optional[NumericsConfig]:
    """Resolve `role` under cfg.numerics, requiring layer-uniformity.

    Stacked per-layer weights share one array (and one dtype), so a
    site can only be prequantized when every layer resolves to the SAME
    posit config; mixed-over-layers sites stay linear f32 and keep
    quantizing per matmul.
    """
    if not layered:
        segs = [(0, 1, None)]
        n_layers = None
    else:
        n_layers = cfg.n_layers
        segs = layer_segments(cfg.numerics, n_layers)
    resolved = []
    for start, _, _ in segs:
        layer = start if layered else None
        resolved.append(site_for(cfg.numerics, role, layer, n_layers))
    first = resolved[0]
    if any(r != first for r in resolved[1:]):
        return None
    return first


def quantize_params(cfg, params, *, pack: bool = True):
    """Encode policy-selected weights to posit patterns once.

    Returns ``(params_q, meta)`` where ``meta`` maps parameter path ->
    ``{"role", "mode", "n", "es"}`` for every quantized leaf (the
    manifest-ready record).  Only sites whose resolved mode is a posit
    mode are touched; tied embeddings are never quantized (the lm_head
    then serves from the shared f32 embedding, as before).
    """
    meta = {}

    def one(key_path, leaf):
        path = _path_str(key_path)
        role = param_role(path)
        if role is None:
            return leaf
        # enc/dec stacks resolve layer-free in the model (layer-range
        # rules target decoder-only LM depth), so only the main LM
        # stack is layer-sensitive here
        layered = path.startswith("layers/")
        site_cfg = _site_cfg_if_uniform(cfg, role, layered)
        if site_cfg is None or site_cfg.mode not in _POSIT_MODES:
            return leaf
        spec = site_cfg.spec
        bits = encode(jnp.asarray(leaf, jnp.float32), spec)
        if pack and spec.n <= 16:
            bits = pack16(bits)
        meta[path] = {
            "role": role,
            "mode": site_cfg.mode,
            "n": spec.n,
            "es": spec.es,
        }
        return bits

    params_q = jax.tree_util.tree_map_with_path(one, params)
    return params_q, meta


def dequantize_params(params_q, meta, dtype=jnp.float32):
    """Inverse of :func:`quantize_params` (to the posit-grid values);
    everything needed to decode is in ``meta``."""
    from repro.numerics import decode, unpack16
    from repro.numerics.posit import PositSpec

    def one(key_path, leaf):
        path = _path_str(key_path)
        info = meta.get(path)
        if info is None:
            return leaf
        bits = unpack16(leaf) if leaf.dtype == jnp.int16 else leaf
        return decode(bits, PositSpec(info["n"], info["es"])).astype(dtype)

    return jax.tree_util.tree_map_with_path(one, params_q)
