"""Numerics-aware dense layer (pure-pytree params, no framework dep)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .modes import NumericsConfig, nmatmul


def dense_init(
    key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None
):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(x, w, ncfg: NumericsConfig, bias=None):
    """y = x @ w (+ bias), multiplying per the configured numerics mode."""
    y = nmatmul(x, w, ncfg, out_dtype=x.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y
