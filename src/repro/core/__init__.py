"""The paper's contribution as composable numerics modes + dense layer."""
from .dense import dense, dense_init  # noqa: F401
from .modes import (  # noqa: F401
    EXACT_BF16,
    PLAM16,
    POSIT16_QUANT,
    NumericsConfig,
    nmatmul,
    nquant_weight,
)
