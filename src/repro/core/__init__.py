"""The paper's contribution as composable numerics modes + dense layer.

``modes`` is the per-matmul dispatch (NumericsConfig / nmatmul),
``policy`` the per-site resolver (NumericsPolicy / site tags), and
``prequant`` the one-shot posit weight encoding for serving.
"""
from .dense import dense, dense_init  # noqa: F401
from .modes import (  # noqa: F401
    EXACT_BF16,
    PLAM16,
    POSIT16_QUANT,
    NumericsConfig,
    nmatmul,
    nquant_weight,
)
from .policy import (  # noqa: F401
    NumericsPolicy,
    parse_policy,
    policy_from_dict,
    policy_to_dict,
    policy_to_str,
    site,
    site_for,
)
from .prequant import dequantize_params, quantize_params  # noqa: F401
