"""Mesh-aware sharding rules: DP x TP (+pod), EP for MoE, SP for long KV.

Models call :func:`constrain` with *logical* axis names; when a mesh
context is active these become `with_sharding_constraint`, otherwise
they are no-ops (unit tests, single host).  Parameter shardings are
derived from pytree paths by :func:`param_shardings`.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical -> physical mesh axes
_LOGICAL = {
    "batch": ("pod", "data"),  # gradient/data parallel (pod folds into DP)
    "model": ("model",),       # tensor/expert parallel
    "seq": ("data",),          # sequence parallel (long-context KV)
    "seq_tp": ("model",),      # KV-cache seq sharded over TP axis (GQA kv < tp)
    None: None,
}

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    tok = _MESH.set(mesh)
    try:
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else contextlib.nullcontext():
            yield mesh
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def _resolve(mesh: Mesh, logical):
    """Logical axis -> physical axes present in this mesh (or None)."""
    if logical is None:
        return None
    phys = [a for a in _LOGICAL[logical] if a in mesh.axis_names]
    if not phys:
        return None
    return tuple(phys) if len(phys) > 1 else phys[0]


def pspec(mesh: Mesh, dims) -> P:
    return P(*[_resolve(mesh, d) for d in dims])


def constrain(x, *dims):
    """Constrain activation sharding by logical dims; no-op without mesh."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    if x.ndim != len(dims):
        raise ValueError(f"rank {x.ndim} vs dims {dims}")
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec(mesh, dims)))


# ---------------------------------------------------------------------------
# parameter sharding rules (matched against '/'-joined pytree paths)
# ---------------------------------------------------------------------------
# Megatron-style TP: column-parallel in-projections, row-parallel
# out-projections; vocab-parallel embeddings; expert-parallel MoE.
_PARAM_RULES = [
    (r"unembed$", (None, "model")),             # [d, V]
    (r"(^|/)embed$", ("model", None)),          # [V, d] vocab-parallel
    (r"(wq|wk|wv)$", (None, "model")),          # column parallel
    (r"wo$", ("model", None)),                  # row parallel
    (r"(wu|wg)$", (None, "model")),             # MLP up/gate: column
    (r"wd$", ("model", None)),                  # MLP down: row
    (r"moe/(wu|wg)$", (None, None, "model")),   # [E, d, ff]: TP inside expert
    (r"moe/wd$", (None, "model", None)),
    (r"moe/router$", (None, None)),
    (r"in_proj$", (None, "model")),             # mamba in: column
    (r"out_proj$", ("model", None)),            # mamba out: row
]
# MoE expert-parallel alternative (E over model axis) is selected by
# rule-set name; see expert_parallel_rules().
_PARAM_RULES_EP = [
    (r"moe/(wu|wg)$", ("model", None, None)),   # [E, d, ff]: experts sharded
    (r"moe/wd$", ("model", None, None)),
] + _PARAM_RULES


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_param(path: str, ndim: int, rules=None) -> tuple:
    for pat, dims in (rules or _PARAM_RULES):
        if re.search(pat, path):
            if len(dims) < ndim:  # stacked-layer leading axes -> replicated
                dims = (None,) * (ndim - len(dims)) + tuple(dims)
            return dims
    return (None,) * ndim


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        out = 1
        for a in phys:
            out *= mesh.shape[a]
        return out
    return mesh.shape[phys]


def sanitize(mesh: Mesh, dims, shape):
    """Drop shardings whose dimension size is not divisible (e.g. a
    49155-entry vocab over a 16-way model axis, or batch 1 over data)."""
    out = []
    for i, d in enumerate(dims):
        phys = _resolve(mesh, d)
        if phys is not None and shape[i] % _axis_size(mesh, phys) != 0:
            d = None
        out.append(d)
    return tuple(out)


def param_shardings(mesh: Mesh, params, rules=None):
    """NamedSharding pytree for a parameter pytree."""
    def one(path, leaf):
        dims = spec_for_param(_path_str(path), leaf.ndim, rules)
        dims = sanitize(mesh, dims, leaf.shape)
        return NamedSharding(mesh, pspec(mesh, dims))

    return jax.tree_util.tree_map_with_path(one, params)


def expert_parallel_rules():
    return _PARAM_RULES_EP


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def paged_pool_spec(mesh: Mesh, shape) -> NamedSharding:
    """Sharding for a paged KV pool [L, num_blocks, block_size, kv, hd].

    Prefer tensor-parallel over the kv-head axis — it matches the
    column-parallel wk/wv projections, so the per-token pool scatter in
    paged decode stays local to each shard.  When GQA leaves fewer kv
    heads than the model axis (kv % tp != 0) fall back to the ``seq_tp``
    rule: positions-within-block sharded over the model axis (the
    gather-attend path partitions cleanly under GSPMD).  If neither
    divides, replicate.  Block tables and the allocator never shard —
    they are host-side numpy, replicated into every jitted step.
    """
    dims = sanitize(mesh, (None, None, None, "model", None), shape)
    if dims[3] is None:
        dims = sanitize(mesh, (None, None, "seq_tp", None, None), shape)
    return NamedSharding(mesh, pspec(mesh, dims))
