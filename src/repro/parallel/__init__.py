"""Mesh construction + named-axis sharding annotations (TP/DP/EP)."""
from .sharding import (  # noqa: F401
    constrain,
    current_mesh,
    expert_parallel_rules,
    param_shardings,
    pspec,
    spec_for_param,
    use_mesh,
)
