"""Straggler detection + mitigation for synchronous data-parallel steps.

At 1000+ nodes, synchronous training runs at the speed of the slowest
worker.  This module provides the control-plane pieces that a cluster
launcher hooks into:

* :class:`StepTimer` — robust online step-time statistics (median/MAD,
  not mean/std: step-time distributions are heavy-tailed) with z-score
  straggler flagging.
* :class:`StragglerPolicy` — the decision logic: after `patience`
  consecutive flagged steps attributable to the same host (identified
  by the launcher's health probes) it escalates DROP (elastic resize to
  a smaller data axis: checkpoint -> rebuild mesh without the host ->
  restore; the stateless data pipeline replays exactly) or, when spare
  capacity exists, SWAP (backup worker takes the shard).
* :func:`run_with_straggler_sim` — a harness that drives a real train
  loop with injected slowdowns and asserts detection, used by the tests
  and the fault-tolerance drill in examples/.

On real TPU pods the per-step host timings come from the launcher's
heartbeats; here they are wall-clock measured (and injectable).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepTimer:
    window: int = 50
    z_threshold: float = 4.0
    min_samples: int = 8

    def __post_init__(self):
        self._times = deque(maxlen=self.window)

    def observe(self, seconds: float) -> bool:
        """Record a step time; returns True if it is a straggler step."""
        flagged = False
        if len(self._times) >= self.min_samples:
            med = self._median()
            mad = self._mad(med)
            if mad > 0 and (seconds - med) / (1.4826 * mad) > self.z_threshold:
                flagged = True
            elif mad == 0 and seconds > 2.0 * med > 0:
                flagged = True
        if not flagged:  # don't poison the window with straggler samples
            self._times.append(seconds)
        return flagged

    def _median(self):
        s = sorted(self._times)
        return s[len(s) // 2]

    def _mad(self, med):
        s = sorted(abs(t - med) for t in self._times)
        return s[len(s) // 2]


@dataclasses.dataclass
class StragglerPolicy:
    patience: int = 3  # consecutive flagged steps before escalation
    action: str = "drop"  # drop (elastic resize) | swap (backup worker)

    def __post_init__(self):
        self._streak = 0
        self.events: List[dict] = []

    def step(self, step_idx: int, flagged: bool) -> Optional[str]:
        """Returns the escalation action when the streak exceeds patience."""
        if flagged:
            self._streak += 1
            if self._streak >= self.patience:
                self.events.append({"step": step_idx, "action": self.action})
                self._streak = 0
                return self.action
        else:
            self._streak = 0
        return None


def run_with_straggler_sim(
    step_fn: Callable[[int], None],
    num_steps: int,
    *,
    slow_steps: dict,  # step -> extra seconds
    timer: Optional[StepTimer] = None,
    policy: Optional[StragglerPolicy] = None,
    base_step_seconds: Optional[float] = None,
):
    """Drive `step_fn`, injecting slowdowns; returns (flags, escalations).

    base_step_seconds: when set, use this fixed per-step time instead of
    wall-clock — hermetic mode for tests/CI, where scheduler jitter on a
    loaded machine would otherwise inject phantom stragglers.
    """
    timer = timer or StepTimer()
    policy = policy or StragglerPolicy()
    flags = []
    for i in range(num_steps):
        t0 = time.perf_counter()
        step_fn(i)
        if base_step_seconds is None:
            elapsed = time.perf_counter() - t0
        else:
            elapsed = base_step_seconds
        elapsed += slow_steps.get(i, 0.0)
        flagged = timer.observe(elapsed)
        flags.append(flagged)
        policy.step(i, flagged)
    return flags, policy.events
