"""Atomic, step-tagged checkpointing with restart/elastic support.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp
dir and atomically renamed (a crash mid-save never corrupts the latest
checkpoint).  Arrays are gathered to host numpy; on restore they are
re-placed under whatever mesh/sharding the *new* run uses, which is
what makes elastic resizing (different data-axis width) work.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None, keep: int = 3):
    """Synchronous atomic save of a pytree."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
        "extra": extra or {},
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, **kw) -> threading.Thread:
    """Non-blocking save: device->host copy happens first (cheap on CPU,
    on TPU it overlaps the next step), file I/O on a worker thread."""
    host_tree = jax.tree.map(np.asarray, tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree), kwargs=kw, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like_tree, *, step: Optional[int] = None, shardings=None):
    """Restore into the structure of `like_tree`; optionally re-place
    each leaf with `shardings` (elastic restore under a new mesh)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "checkpoint/model structure mismatch"
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        assert tuple(old.shape) == tuple(new.shape), (old.shape, new.shape)
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest


POLICY_KEY = "numerics_policy"


def policy_extra(numerics) -> dict:
    """Manifest-extra dict carrying a serialized numerics policy."""
    from repro.core.policy import policy_to_dict

    return {POLICY_KEY: policy_to_dict(numerics)}


def manifest_policy(manifest: dict):
    """Rebuild the NumericsPolicy stored by :func:`policy_extra`, or
    None when the checkpoint carries no policy metadata."""
    from repro.core.policy import policy_from_dict

    data = (manifest.get("extra") or {}).get(POLICY_KEY)
    return None if data is None else policy_from_dict(data)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
