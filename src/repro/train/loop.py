"""Training loop: step factory (grads + optimizer), gradient
accumulation, optional int8 gradient compression for the cross-pod
all-reduce, checkpoint/restart and failure recovery.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptConfig, apply_updates, init_state

from . import checkpoint as ckpt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    grad_accum: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    compress_grads: bool = False  # int8 stochastic-rounded gradient exchange
    # manifest-extra dict stored with every checkpoint (e.g. the
    # serialized numerics policy: {"numerics_policy": policy_to_dict(p)})
    ckpt_extra: Optional[dict] = None


def _int8_compress(g, key):
    """Stochastic-rounded int8 quantization of a gradient tensor.

    Used to model compressed cross-pod gradient exchange: the all-reduce
    then moves 1/4 of the bytes.  Unbiased (E[deq] == g).
    """
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    With grad_accum > 1 the global batch is split on the leading axis
    into microbatches accumulated via lax.scan (activation memory drops
    by the accumulation factor).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape(tcfg.grad_accum, -1, *x.shape[1:]) if x.ndim >= 1 else x,
                    b,
                )

            mb = micro(batch)

            def body(acc, xs):
                loss, grads = grads_of(params, xs)
                acc_loss, acc_g = acc
                return (acc_loss + loss, jax.tree.map(jnp.add, acc_g, grads)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
            loss = loss / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
        else:
            loss, grads = grads_of(params, batch)

        if tcfg.compress_grads:
            key = jax.random.fold_in(jax.random.PRNGKey(17), opt_state["step"])
            keys = iter(jax.random.split(key, len(jax.tree.leaves(grads))))
            grads = jax.tree.map(lambda g: _int8_compress(g, next(keys)), grads)

        new_params, new_state = apply_updates(tcfg.opt, params, grads, opt_state)
        metrics = {"loss": loss, "step": new_state["step"]}
        return new_params, new_state, metrics

    return train_step


class FailureInjector:
    """Deterministic crash simulator for fault-tolerance tests/drills."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.tripped = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.tripped:
            self.tripped.add(step)
            raise RuntimeError(f"[injected] node failure at step {step}")


def run(
    *,
    loss_fn,
    init_params_fn,
    batch_fn,  # step -> batch
    tcfg: TrainConfig,
    num_steps: int,
    failure: Optional[FailureInjector] = None,
    max_restarts: int = 3,
    jit: bool = True,
):
    """Drive training with checkpoint/restart.  On an (injected or real)
    step failure the loop restores the last checkpoint and continues —
    the data pipeline is stateless so batches replay identically."""
    step_fn = make_train_step(loss_fn, tcfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def fresh():
        params = init_params_fn()
        return params, init_state(tcfg.opt, params), 0

    params, opt_state, start = fresh()
    if tcfg.ckpt_dir and (s := ckpt_lib.latest_step(tcfg.ckpt_dir)) is not None:
        (params, opt_state), _ = ckpt_lib.restore(tcfg.ckpt_dir, (params, opt_state))
        start = s

    restarts = 0
    history = []
    step = start
    while step < num_steps:
        try:
            if failure is not None:
                failure.maybe_fail(step)
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % tcfg.log_every == 0:
                history.append((step, float(metrics["loss"])))
            step += 1
            if tcfg.ckpt_dir and step % tcfg.ckpt_every == 0:
                ckpt_lib.save(
                    tcfg.ckpt_dir, step, (params, opt_state), extra=tcfg.ckpt_extra
                )
        except RuntimeError as e:
            if "[injected]" not in str(e) or restarts >= max_restarts:
                raise
            restarts += 1
            if tcfg.ckpt_dir and (s := ckpt_lib.latest_step(tcfg.ckpt_dir)) is not None:
                (params, opt_state), _ = ckpt_lib.restore(tcfg.ckpt_dir, (params, opt_state))
                step = s
            else:
                params, opt_state, step = fresh()
    if tcfg.ckpt_dir:
        ckpt_lib.save(tcfg.ckpt_dir, step, (params, opt_state), extra=tcfg.ckpt_extra)
    return params, opt_state, {"history": history, "restarts": restarts, "final_step": step}
