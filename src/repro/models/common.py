"""Shared model components: norms, rotary embeddings (incl. M-RoPE),
token embeddings.  Pure functions over pytree params."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    return _rmsnorm_core(x, scale, eps), (x, scale)


def _rmsnorm_bwd(eps, res, ct):
    # Keeps the cotangent boundary at the ACTIVATION dtype: upstream
    # sums of branch cotangents (and the TP all-reduces carrying them)
    # stay bf16 instead of being reassociated into this f32 math
    # (EXPERIMENTS.md §Perf, command-r hillclimb).
    x, scale = res
    xf = x.astype(jnp.float32)
    g = ct.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    sg = g * scale.astype(jnp.float32)
    dx = inv * sg - xf * (inv ** 3) * jnp.mean(sg * xf, axis=-1, keepdims=True)
    dscale = jnp.sum(g * xf * inv, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p, x, eps: float = 1e-6):
    return _rmsnorm_core(x, p["scale"], eps)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x, positions, theta: float, sections=None):
    """Rotary embedding.  x: [B, S, H, hd]; positions: [B, S] int32, or
    [3, B, S] for M-RoPE with ``sections`` = 3 half-dim section sizes
    (temporal, height, width), as in Qwen2-VL.
    """
    half = x.shape[-1] // 2
    inv = rope_freqs(x.shape[-1], theta)  # [half]
    if sections is None:
        ang = positions.astype(jnp.float32)[..., None] * inv  # [B,S,half]
    else:
        assert sum(sections) == half, (sections, half)
        parts = []
        start = 0
        for i, sec in enumerate(sections):
            ang_i = positions[i].astype(jnp.float32)[..., None] * inv[start:start + sec]
            parts.append(ang_i)
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]  # [B,S,1,half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def multi_token_positions(lengths, width: int, mrope: bool = False):
    """Per-sequence positions for a `width`-token span starting at each
    sequence's cache length.

    lengths: [B] int32 — tokens already in each sequence's cache; token
    j of the span sits at position ``lengths[b] + j``.  Returns [B, W]
    (or [3, B, W] broadcast for text-only M-RoPE).  This is the batched
    generalization of `default_positions(..., offset=cache_len)`, which
    assumes one shared scalar offset — continuous batching retires and
    admits sequences mid-flight, so every slot has its own offset, and
    speculative verify scores k+1 positions per slot in one call.
    """
    pos = lengths.astype(jnp.int32)[:, None] + jnp.arange(width, dtype=jnp.int32)
    if mrope:
        pos = jnp.broadcast_to(pos[None], (3,) + pos.shape)
    return pos


def decode_positions(lengths, mrope: bool = False):
    """Single-token special case of `multi_token_positions`."""
    return multi_token_positions(lengths, 1, mrope)


def causal_mask(s_q: int, s_k: int, q_offset=0):
    """[s_q, s_k] bool mask; True = attend."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    return ki <= qi


def stack_layer_params(init_one, key, n_layers: int):
    """vmap a per-layer init over layer keys -> stacked [L, ...] pytree."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def tree_slice(tree, start: int, size: int, axis: int = 0):
    """Static slice of every leaf of a stacked-[L] pytree."""
    return jax.tree.map(
        lambda a: jax.lax.slice_in_dim(a, start, start + size, axis=axis), tree
    )


def scan_policy_segments(
    numerics, n_layers, layer_params, caches, x, scan_segment, *, start=0, size=None
):
    """Run a layer-stack scan split into policy-uniform segments.

    Shared scaffolding for every backbone: layer-range numerics rules
    split ``[start, start + size)`` into segments
    (``core.policy.layer_segments``); each segment's slice of the
    stacked params (and caches) is scanned by ``scan_segment(x,
    seg_params, seg_caches, nsite) -> (x, new_caches_or_None)`` and the
    per-segment caches are concatenated back on the stack axis.  A
    layer-uniform policy is a single segment driving the exact
    unsegmented scan — the bit-identity pin relies on that.
    """
    from repro.core.policy import layer_segments

    segments = layer_segments(numerics, n_layers, start, size)
    if len(segments) == 1:
        return scan_segment(x, layer_params, caches, segments[0][2])
    outs = []
    for seg_start, seg_size, nsite in segments:
        sp = tree_slice(layer_params, seg_start, seg_size)
        sc = None if caches is None else tree_slice(caches, seg_start, seg_size)
        x, nc = scan_segment(x, sp, sc, nsite)
        outs.append(nc)
    if outs[0] is None:
        return x, None
    return x, jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *outs)
