"""Gated / plain MLP blocks, numerics-aware."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense, dense_init
from repro.core.modes import NumericsConfig

ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron/minitron
}


def mlp_init(key, d: int, d_ff: int, glu: bool, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wu": dense_init(k1, d, d_ff, dtype), "wd": dense_init(k2, d_ff, d, dtype)}
    if glu:
        p["wg"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp_apply(p, x, ncfg: NumericsConfig, act: str = "silu"):
    fn = ACTS[act]
    up = dense(x, p["wu"], ncfg)
    if "wg" in p:
        up = fn(dense(x, p["wg"], ncfg)) * up
    else:
        up = fn(up)
    return dense(up, p["wd"], ncfg)
