"""Gated / plain MLP blocks, numerics-aware (sites ``mlp.{up,gate,down}``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense, dense_init
from repro.core.policy import SiteNumerics, site

ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),  # nemotron/minitron
}


def mlp_init(key, d: int, d_ff: int, glu: bool, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wu": dense_init(k1, d, d_ff, dtype), "wd": dense_init(k2, d_ff, d, dtype)}
    if glu:
        p["wg"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp_apply(p, x, ncfg: SiteNumerics, act: str = "silu", role: str = "mlp"):
    """``role`` prefixes the site tags — MoE shared experts pass
    ``"moe.shared"`` so a policy can target them separately."""
    fn = ACTS[act]
    up = dense(x, p["wu"], site(ncfg, f"{role}.up"))
    if "wg" in p:
        up = fn(dense(x, p["wg"], site(ncfg, f"{role}.gate"))) * up
    else:
        up = fn(up)
    return dense(up, p["wd"], site(ncfg, f"{role}.down"))
