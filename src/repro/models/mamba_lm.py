"""Attention-free Mamba2 LM (mamba2-780m).

Numerics sites: ``ssm.proj.in`` / ``ssm.proj.out`` inside each block,
``lm_head`` for the unembedding.  Layer-range policy rules segment the
layer scan exactly as in the transformer backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dense import dense, dense_init
from repro.core.policy import site_for
from repro.parallel.sharding import constrain

from .common import (
    embed_init,
    rmsnorm,
    rmsnorm_init,
    scan_policy_segments,
    stack_layer_params,
)
from .ssm import mamba2_apply, mamba2_cache_init, mamba2_init
from .transformer import lm_loss_chunked


def _kw(cfg: ModelConfig):
    return dict(expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk)


def mamba_lm_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ku = jax.random.split(key, 3)

    def one(k):
        return {
            "ln": rmsnorm_init(cfg.d_model, dtype),
            "mamba": mamba2_init(k, cfg.d_model, expand=cfg.ssm_expand,
                                 head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                                 d_conv=cfg.ssm_conv, dtype=dtype),
        }

    return {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stack_layer_params(one, km, cfg.n_layers),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "unembed": dense_init(ku, cfg.d_model, cfg.vocab, dtype),
    }


def backbone(cfg: ModelConfig, params, embeds, caches=None):
    x = constrain(embeds, "batch", None, None)

    def scan_segment(x, layer_params, seg_caches, nsite):
        def body(x, scanned):
            if seg_caches is None:
                lp, c = scanned, None
            else:
                lp, c = scanned
            h, nc = mamba2_apply(
                lp["mamba"], rmsnorm(lp["ln"], x), nsite, cache=c, **_kw(cfg)
            )
            return constrain(x + h, "batch", None, None), nc

        xs = layer_params if seg_caches is None else (layer_params, seg_caches)
        return jax.lax.scan(body, x, xs)

    x, new_caches = scan_policy_segments(
        cfg.numerics, cfg.n_layers, params["layers"], caches, x, scan_segment
    )
    return rmsnorm(params["ln_f"], x), (None if caches is None else new_caches)


def train_loss(cfg: ModelConfig, params, batch):
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.act_dtype))
    hidden, _ = backbone(cfg, params, x)
    return lm_loss_chunked(cfg, {"unembed": params["unembed"]}, hidden, batch["labels"])


def cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    one = mamba2_cache_init(batch, cfg.d_model, expand=cfg.ssm_expand,
                            head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                            d_conv=cfg.ssm_conv, dtype=dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)).copy(), one)


def _head_cfg(cfg: ModelConfig):
    return site_for(cfg.numerics, "lm_head", n_layers=cfg.n_layers)


def prefill(cfg: ModelConfig, params, tokens, caches):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))
    hidden, new_caches = backbone(cfg, params, x, caches)
    logits = dense(hidden[:, -1:, :], params["unembed"], _head_cfg(cfg))
    return logits, new_caches


def decode_step(cfg: ModelConfig, params, token, caches, cache_len):
    del cache_len  # SSM state is position-free
    x = params["embed"][token].astype(jnp.dtype(cfg.act_dtype))
    hidden, new_caches = backbone(cfg, params, x, caches)
    logits = dense(hidden, params["unembed"], _head_cfg(cfg))
    return logits, new_caches
