"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch.

Deployment-grade dropping MoE (MaxText-style): tokens are scattered
into per-expert capacity buffers (overflow dropped), experts run as
stacked matmuls (sharded over the `model` mesh axis = expert
parallelism), and results are combined with the gate probabilities.
Router logits/gates stay in exact f32 (routing is control flow); the
expert FFN matmuls are numerics-aware (PLAM / posit-quant).

Supports DeepSeekMoE-style shared experts (always-on) alongside the
routed ones.

Numerics sites: ``moe.router`` (baseline policy rule keeps it exact
f32 — routing is control flow — unless a policy explicitly overrides
it), ``moe.expert.{up,gate,down}`` for the routed FFNs and
``moe.shared.{up,gate,down}`` for the shared experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense_init
from repro.core.modes import nmatmul
from repro.core.policy import SiteNumerics, site

from .mlp import ACTS, mlp_apply, mlp_init


def moe_init(key, d: int, n_experts: int, moe_d_ff: int, n_shared: int, shared_d_ff: int, glu: bool, dtype=jnp.float32):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    def einit(k, i, o):
        keys = jax.random.split(k, n_experts)
        return jax.vmap(lambda kk: dense_init(kk, i, o, dtype))(keys)
    p = {
        "router": dense_init(kr, d, n_experts, jnp.float32),
        "wg": einit(kg, d, moe_d_ff),
        "wu": einit(ku, d, moe_d_ff),
        "wd": einit(kd, moe_d_ff, d),
    }
    if n_shared:
        p["shared"] = mlp_init(ks, d, shared_d_ff * n_shared, glu, dtype)
    return p


def _dispatch_group(xf, router_logits, ncfg, p, *, n_experts, top_k, cap, act):
    """Capacity dispatch + expert FFNs + combine for ONE token group.

    xf: [Tg, d].  All index math is group-local, so under vmap with the
    group axis sharded over `batch` the scatter/gather never crosses
    data shards (the cross-shard traffic becomes the expert einsum's
    all-to-all, inserted by SPMD where expert parallelism demands it).
    """
    t, d = xf.shape
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)  # [Tg, K]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    eid_f = eid.reshape(-1)  # [Tg*K]
    oh = jax.nn.one_hot(eid_f, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh  # rank within expert, group-local
    pos = jnp.take_along_axis(pos, eid_f[:, None], axis=-1)[:, 0]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    contrib = jnp.where(keep[:, None], xf[tok_idx], 0).astype(xf.dtype)
    buf = jnp.zeros((n_experts, cap, d), xf.dtype).at[eid_f, pos_c].add(contrib)

    fn = ACTS[act]
    up_cfg = site(ncfg, "moe.expert.up")
    gate_cfg = site(ncfg, "moe.expert.gate")
    down_cfg = site(ncfg, "moe.expert.down")

    def expert(xe, wg, wu, wd):
        up = nmatmul(xe, wu, up_cfg, out_dtype=xe.dtype)
        up = fn(nmatmul(xe, wg, gate_cfg, out_dtype=xe.dtype)) * up
        return nmatmul(up, wd, down_cfg, out_dtype=xe.dtype)

    out_buf = jax.vmap(expert)(buf, p["wg"], p["wu"], p["wd"])  # [E, C, d]

    gathered = out_buf[eid_f, pos_c]
    gathered = jnp.where(keep[:, None], gathered, 0)
    return (gathered.reshape(t, top_k, d) * gate[..., None].astype(xf.dtype)).sum(axis=1)


def moe_apply(
    p,
    x,
    ncfg: SiteNumerics,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    groups: int = 1,
):
    """x: [B, S, d] -> [B, S, d].

    groups > 1 enables shard-local dispatch (set groups = the data-
    parallel degree): capacity bookkeeping (cumsum/scatter/gather) stays
    inside each data shard instead of spanning the global batch, which
    removes the O(E*C_global*d) cross-shard all-reduces of the naive
    dispatch (EXPERIMENTS.md §Perf, deepseek hillclimb).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    # the router goes through the policy resolver; the built-in
    # ``moe.router=f32`` baseline rule reproduces the old inline
    # NumericsConfig(mode="f32") escape hatch unless overridden
    logits = nmatmul(xf, p["router"], site(ncfg, "moe.router"), out_dtype=jnp.float32)

    g = groups if t % max(groups, 1) == 0 else 1
    tg = t // g
    cap = max(1, int(tg * top_k / n_experts * capacity_factor))

    if g == 1:
        combined = _dispatch_group(
            xf, logits, ncfg, p, n_experts=n_experts, top_k=top_k, cap=cap, act=act)
    else:
        from repro.parallel.sharding import constrain

        xg = constrain(xf.reshape(g, tg, d), "batch", None, None)
        lg = constrain(logits.reshape(g, tg, n_experts), "batch", None, None)
        combined = jax.vmap(
            lambda xe, le: _dispatch_group(
                xe, le, ncfg, p, n_experts=n_experts, top_k=top_k, cap=cap, act=act)
        )(xg, lg)
        combined = combined.reshape(t, d)

    if "shared" in p:
        combined = combined + mlp_apply(p["shared"], xf, ncfg, act, role="moe.shared")
    return combined.reshape(b, s, d)


def aux_load_balance_loss(logits, eid, n_experts: int):
    """Switch-style load-balance auxiliary loss (mean prob x mean load)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    load = jnp.mean(jax.nn.one_hot(eid[..., 0], n_experts, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(imp * load)
