"""Model zoo: one scan-based implementation per architecture family."""
from .registry import ModelAPI, build  # noqa: F401
