"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

The shared transformer block (one set of weights) is applied every
``shared_attn_every`` SSM layers; its input is concat(hidden, original
embeddings) — 2*d_model wide, as in Zamba — projected back to d_model.
(Zamba2's per-invocation LoRA deltas on the shared weights are omitted;
see DESIGN.md.)

Layers are scanned in groups between shared-block invocations so the
HLO stays small; each invocation has its own KV cache slot.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dense import dense, dense_init
from repro.core.policy import bind, site, site_for
from repro.parallel.sharding import constrain

from .attention import attn_apply, attn_init
from .common import (
    embed_init,
    rmsnorm,
    rmsnorm_init,
    scan_policy_segments,
    stack_layer_params,
    tree_slice,
)
from .mlp import mlp_apply, mlp_init
from .ssm import mamba2_apply, mamba2_cache_init, mamba2_init
from .transformer import lm_loss_chunked


def _ssm_kw(cfg: ModelConfig):
    return dict(expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk)


def hybrid_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ks, ku = jax.random.split(key, 4)
    d2 = 2 * cfg.d_model

    def one(k):
        return {
            "ln": rmsnorm_init(cfg.d_model, dtype),
            "mamba": mamba2_init(
                k, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, d_conv=cfg.ssm_conv, dtype=dtype,
            ),
        }

    k1, k2, k3 = jax.random.split(ks, 3)
    shared = {
        "ln1": rmsnorm_init(d2, dtype),
        "attn": attn_init(k1, d2, cfg.n_heads, cfg.n_kv, 2 * cfg.d_model // cfg.n_heads, dtype),
        "ln2": rmsnorm_init(d2, dtype),
        "mlp": mlp_init(k2, d2, cfg.d_ff, cfg.glu, dtype),
        "out_proj": dense_init(k3, d2, cfg.d_model, dtype),
    }
    return {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stack_layer_params(one, km, cfg.n_layers),
        "shared": shared,
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
        "unembed": dense_init(ku, cfg.d_model, cfg.vocab, dtype),
    }


def n_shared_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def _shared_block(cfg, sp, x, x0, positions, kv_slice, cache_len):
    """Concat(hidden, embeds) -> shared attn + MLP -> proj back to d.

    The block is SHARED across invocations (one set of weights), so its
    sites resolve layer-free; the down-projection back to d_model is
    the ``hybrid.proj`` site.
    """
    d2 = 2 * cfg.d_model
    nsite = bind(cfg.numerics, None, cfg.n_layers)
    cat = jnp.concatenate([x, x0], axis=-1)
    h, new_kv = attn_apply(
        sp["attn"], rmsnorm(sp["ln1"], cat), nsite,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=d2 // cfg.n_heads,
        positions=positions, rope_theta=cfg.rope_theta,
        kv_cache=kv_slice, cache_len=cache_len,
    )
    cat = cat + h
    cat = cat + mlp_apply(sp["mlp"], rmsnorm(sp["ln2"], cat), nsite, cfg.act)
    return x + dense(cat, sp["out_proj"], site(nsite, "hybrid.proj")), new_kv


def _scan_group(cfg, group_params, x, caches, start_layer: int, group_size: int):
    """Scan a stacked group of mamba layers (absolute layers
    [start_layer, start_layer + group_size)), segmenting on layer-range
    numerics rules.  caches: pytree [G,...] or None."""

    def scan_segment(x, seg_params, seg_caches, nsite):
        def body(x, scanned):
            if seg_caches is None:
                lp, c = scanned, None
            else:
                lp, c = scanned
            h, new_c = mamba2_apply(lp["mamba"], rmsnorm(lp["ln"], x), nsite,
                                    cache=c, **_ssm_kw(cfg))
            return constrain(x + h, "batch", None, None), new_c

        xs = seg_params if seg_caches is None else (seg_params, seg_caches)
        return jax.lax.scan(body, x, xs)

    return scan_policy_segments(
        cfg.numerics, cfg.n_layers, group_params, caches, x, scan_segment,
        start=start_layer, size=group_size,
    )


def hybrid_backbone(cfg: ModelConfig, params, embeds, positions, caches=None, cache_len=None):
    """caches: None (training) or dict with 'ssm' pytree [L,...],
    'shared_k'/'shared_v' [n_inv, B, S, kv, hd2]."""
    x = constrain(embeds, "batch", None, None)
    x0 = embeds
    every = cfg.shared_attn_every
    n_inv = n_shared_invocations(cfg)
    new_ssm, new_k, new_v = [], [], []
    layer = 0
    for inv in range(n_inv):
        gp = tree_slice(params["layers"], layer, every)
        gc = None if caches is None else jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, layer, layer + every, axis=0), caches["ssm"])
        x, nc = _scan_group(cfg, gp, x, gc, layer, every)
        if caches is not None:
            new_ssm.append(nc)
        kv_slice = None if caches is None else (caches["shared_k"][inv], caches["shared_v"][inv])
        x, skv = _shared_block(cfg, params["shared"], x, x0, positions, kv_slice, cache_len)
        if caches is not None:
            new_k.append(skv[0])
            new_v.append(skv[1])
        layer += every
    rem = cfg.n_layers - layer
    if rem:
        gp = tree_slice(params["layers"], layer, rem)
        gc = None if caches is None else jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, layer, layer + rem, axis=0), caches["ssm"])
        x, nc = _scan_group(cfg, gp, x, gc, layer, rem)
        if caches is not None:
            new_ssm.append(nc)
    x = rmsnorm(params["ln_f"], x)
    if caches is None:
        return x, None
    new_caches = {
        "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm),
        "shared_k": jnp.stack(new_k),
        "shared_v": jnp.stack(new_v),
    }
    return x, new_caches


def cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    one = mamba2_cache_init(batch, cfg.d_model, expand=cfg.ssm_expand,
                            head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                            d_conv=cfg.ssm_conv, dtype=dtype)
    ssm = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)).copy(), one)
    n_inv = n_shared_invocations(cfg)
    hd2 = 2 * cfg.d_model // cfg.n_heads
    kv_shape = (n_inv, batch, max_len, cfg.n_kv, hd2)
    return {"ssm": ssm, "shared_k": jnp.zeros(kv_shape, dtype), "shared_v": jnp.zeros(kv_shape, dtype)}


def train_loss(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    hidden, _ = hybrid_backbone(cfg, params, x, positions)
    return lm_loss_chunked(cfg, {"unembed": params["unembed"]}, hidden, batch["labels"])


def _head_cfg(cfg: ModelConfig):
    return site_for(cfg.numerics, "lm_head", n_layers=cfg.n_layers)


def prefill(cfg: ModelConfig, params, tokens, caches):
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    hidden, new_caches = hybrid_backbone(cfg, params, x, positions, caches, jnp.int32(0))
    logits = dense(hidden[:, -1:, :], params["unembed"], _head_cfg(cfg))
    return logits, new_caches


def decode_step(cfg: ModelConfig, params, token, caches, cache_len):
    b = token.shape[0]
    x = params["embed"][token].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(cache_len + jnp.zeros((b, 1), jnp.int32), (b, 1))
    hidden, new_caches = hybrid_backbone(cfg, params, x, positions, caches, cache_len)
    logits = dense(hidden, params["unembed"], _head_cfg(cfg))
    return logits, new_caches
