"""Uniform model API over all architecture families + dry-run input specs."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

from . import encdec as _encdec
from . import hybrid as _hybrid
from . import mamba_lm as _mamba
from . import transformer as _tf

VLM_PATCHES = 1024  # stub vision frontend: 32x32 patch grid (reduced: 16)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable  # (params, batch) -> scalar loss
    prefill: Callable  # (params, batch) -> (logits, caches)
    decode_step: Callable  # (params, batch_with_caches) -> (logits, caches)
    train_inputs: Callable  # (ShapeSpec) -> batch of ShapeDtypeStruct
    prefill_inputs: Callable
    decode_inputs: Callable
    # paged KV-cache serving path (continuous batching); None for
    # families without a paged layout (ssm/hybrid state caches, encdec)
    paged_pool_init: Optional[Callable] = None  # (num_blocks, block_size) -> pools
    paged_prefill: Optional[Callable] = None  # (params, tokens, kp, vp, block_ids, true_len)
    paged_prefill_chunk: Optional[Callable] = None  # (params, tokens, kp, vp, block_ids, cache_len, last_idx)
    paged_decode_step: Optional[Callable] = None  # (params, token, kp, vp, tables, lengths)
    paged_score_tokens: Optional[Callable] = None  # (params, tokens [B,W], kp, vp, tables, lengths)


def _patches(cfg: ModelConfig) -> int:
    return VLM_PATCHES if cfg.d_model > 512 else 16


def build(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    act_dt = jnp.dtype(cfg.act_dtype)
    cache_dt = jnp.bfloat16

    if fam in ("dense", "moe", "vlm"):
        def init(key):
            return _tf.lm_init(cfg, key)

        def train_loss(params, batch):
            return _tf.train_loss(cfg, params, batch)

        def prefill(params, batch):
            tokens = batch["tokens"]
            b, s = tokens.shape
            if fam == "vlm":
                # patch embeddings occupy the prefix of the cache window
                x = _tf.embed_tokens(cfg, params, tokens)
                x = jnp.concatenate([batch["embeds_prefix"].astype(x.dtype), x], axis=1)
                s_tot = x.shape[1]
                caches = _tf.kv_cache_init(cfg, b, s_tot, cache_dt)
                positions = _tf.default_positions(cfg, b, s_tot)
                hidden, new_caches = _tf.lm_backbone(
                    cfg, params, x, positions, kv_caches=caches, cache_len=jnp.int32(0))
                logits = _tf.lm_logits(cfg, params, hidden[:, -1:, :])
                return logits, new_caches
            caches = _tf.kv_cache_init(cfg, b, s, cache_dt)
            return _tf.prefill(cfg, params, tokens, caches)

        def decode_step(params, batch):
            return _tf.decode_step(
                cfg, params, batch["token"], batch["kv_caches"], batch["cache_len"])

        def train_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            if fam == "vlm":
                p = _patches(cfg)
                return {
                    "tokens": _sds((b, s - p), jnp.int32),
                    "labels": _sds((b, s - p), jnp.int32),
                    "embeds_prefix": _sds((b, p, cfg.d_model), act_dt),
                }
            return {"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)}

        def prefill_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            if fam == "vlm":
                p = _patches(cfg)
                return {
                    "tokens": _sds((b, s - p), jnp.int32),
                    "embeds_prefix": _sds((b, p, cfg.d_model), act_dt),
                }
            return {"tokens": _sds((b, s), jnp.int32)}

        def decode_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            kv = (cfg.n_layers, b, s, cfg.n_kv, cfg.hd)
            return {
                "token": _sds((b, 1), jnp.int32),
                "kv_caches": (_sds(kv, cache_dt), _sds(kv, cache_dt)),
                "cache_len": _sds((), jnp.int32),
            }

    elif fam == "ssm":
        def init(key):
            return _mamba.mamba_lm_init(cfg, key)

        def train_loss(params, batch):
            return _mamba.train_loss(cfg, params, batch)

        def prefill(params, batch):
            tokens = batch["tokens"]
            caches = _mamba.cache_init(cfg, tokens.shape[0], 0, cache_dt)
            return _mamba.prefill(cfg, params, tokens, caches)

        def decode_step(params, batch):
            return _mamba.decode_step(cfg, params, batch["token"], batch["caches"], batch["cache_len"])

        def train_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            return {"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)}

        def prefill_inputs(shape: ShapeSpec):
            return {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32)}

        def decode_inputs(shape: ShapeSpec):
            b = shape.global_batch
            caches = jax.eval_shape(lambda: _mamba.cache_init(cfg, b, 0, cache_dt))
            return {"token": _sds((b, 1), jnp.int32), "caches": caches,
                    "cache_len": _sds((), jnp.int32)}

    elif fam == "hybrid":
        def init(key):
            return _hybrid.hybrid_init(cfg, key)

        def train_loss(params, batch):
            return _hybrid.train_loss(cfg, params, batch)

        def prefill(params, batch):
            tokens = batch["tokens"]
            caches = _hybrid.cache_init(cfg, tokens.shape[0], tokens.shape[1], cache_dt)
            return _hybrid.prefill(cfg, params, tokens, caches)

        def decode_step(params, batch):
            return _hybrid.decode_step(cfg, params, batch["token"], batch["caches"], batch["cache_len"])

        def train_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            return {"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)}

        def prefill_inputs(shape: ShapeSpec):
            return {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32)}

        def decode_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            caches = jax.eval_shape(lambda: _hybrid.cache_init(cfg, b, s, cache_dt))
            return {"token": _sds((b, 1), jnp.int32), "caches": caches,
                    "cache_len": _sds((), jnp.int32)}

    elif fam == "encdec":
        tgt_len = 4096

        def init(key):
            return _encdec.encdec_init(cfg, key)

        def train_loss(params, batch):
            return _encdec.train_loss(cfg, params, batch)

        def prefill(params, batch):
            tokens = batch["tokens"]
            caches = _encdec.kv_cache_init(cfg, tokens.shape[0], tokens.shape[1], cache_dt)
            return _encdec.prefill(cfg, params, batch["frames"], tokens, caches)

        def decode_step(params, batch):
            return _encdec.decode_step(
                cfg, params, batch["token"], batch["enc_out"], batch["kv_caches"], batch["cache_len"])

        def _tgt(s):
            return min(s, tgt_len) if cfg.d_model > 512 else min(s, 64)

        def train_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            t = _tgt(s)
            return {
                "frames": _sds((b, s, cfg.frontend_dim), act_dt),
                "tokens": _sds((b, t), jnp.int32),
                "labels": _sds((b, t), jnp.int32),
            }

        def prefill_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            return {
                "frames": _sds((b, s, cfg.frontend_dim), act_dt),
                "tokens": _sds((b, _tgt(s)), jnp.int32),
            }

        def decode_inputs(shape: ShapeSpec):
            b, s = shape.global_batch, shape.seq_len
            t = _tgt(s)
            kv = (cfg.dec_layers, b, t, cfg.n_kv, cfg.hd)
            return {
                "token": _sds((b, 1), jnp.int32),
                "enc_out": _sds((b, s, cfg.d_model), act_dt),
                "kv_caches": (_sds(kv, cache_dt), _sds(kv, cache_dt)),
                "cache_len": _sds((), jnp.int32),
            }

    else:  # pragma: no cover
        raise ValueError(fam)

    paged = {}
    if fam in ("dense", "moe"):
        def paged_pool_init(num_blocks, block_size, dtype=cache_dt):
            return _tf.paged_kv_pool_init(cfg, num_blocks, block_size, dtype)

        def paged_prefill(params, tokens, k_pool, v_pool, block_ids, true_len):
            return _tf.paged_prefill(
                cfg, params, tokens, k_pool, v_pool, block_ids, true_len)

        def paged_prefill_chunk(params, tokens, k_pool, v_pool, block_ids,
                                cache_len, last_idx):
            return _tf.paged_prefill_chunk(
                cfg, params, tokens, k_pool, v_pool, block_ids, cache_len,
                last_idx)

        def paged_decode_step(params, token, k_pool, v_pool, block_tables,
                              lengths, use_kernel=None):
            return _tf.paged_decode_step(
                cfg, params, token, k_pool, v_pool, block_tables, lengths,
                use_kernel=use_kernel)

        def paged_score_tokens(params, tokens, k_pool, v_pool, block_tables,
                               lengths):
            return _tf.paged_score_tokens(
                cfg, params, tokens, k_pool, v_pool, block_tables, lengths)

        paged = dict(
            paged_pool_init=paged_pool_init,
            paged_prefill=paged_prefill,
            paged_prefill_chunk=paged_prefill_chunk,
            paged_decode_step=paged_decode_step,
            paged_score_tokens=paged_score_tokens,
        )

    return ModelAPI(
        cfg=cfg, init=init, train_loss=train_loss, prefill=prefill,
        decode_step=decode_step, train_inputs=train_inputs,
        prefill_inputs=prefill_inputs, decode_inputs=decode_inputs,
        **paged,
    )
