"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a stub per the task spec: inputs are precomputed
frame embeddings [B, frames, d].  Encoder is bidirectional; decoder has
causal self-attention + cross-attention over the encoder output.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dense import dense_init
from repro.core.policy import bind, site_for
from repro.parallel.sharding import constrain

from .attention import attn_apply, attn_init, cross_attn_apply, encode_cross_kv
from .common import embed_init, rmsnorm, rmsnorm_init, stack_layer_params
from .mlp import mlp_apply, mlp_init
from .transformer import lm_loss_chunked


def _enc_layer_init(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype),
    }


def _dec_layer_init(cfg: ModelConfig, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_init(cfg, jax.random.fold_in(key, 0), dtype)
    p["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
    p["xattn"] = attn_init(k3, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype)
    return p


def encdec_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, k1, k2, ku, kf = jax.random.split(key, 5)
    return {
        "frontend_proj": dense_init(kf, cfg.frontend_dim, cfg.d_model, dtype),
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "enc_layers": stack_layer_params(partial(_enc_layer_init, cfg, dtype=dtype), k1, cfg.enc_layers),
        "dec_layers": stack_layer_params(partial(_dec_layer_init, cfg, dtype=dtype), k2, cfg.dec_layers),
        "ln_enc": rmsnorm_init(cfg.d_model, dtype),
        "ln_dec": rmsnorm_init(cfg.d_model, dtype),
        "unembed": dense_init(ku, cfg.d_model, cfg.vocab, dtype),
    }


def encode(cfg: ModelConfig, params, frames):
    """frames: [B, S_src, frontend_dim] precomputed (stub frontend).

    Enc/dec stacks resolve their numerics sites layer-free (layer-range
    policy rules target decoder-only LM depth; see docs/numerics.md).
    """
    from repro.core.dense import dense

    nsite = bind(cfg.numerics)
    x = dense(
        frames.astype(jnp.dtype(cfg.act_dtype)),
        params["frontend_proj"],
        site_for(cfg.numerics, "frontend"),
    )
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = constrain(x, "batch", None, None)

    def body(x, lp):
        h, _ = attn_apply(
            lp["attn"], rmsnorm(lp["ln1"], x), nsite,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, rope_theta=cfg.rope_theta, mask="full",
        )
        x = x + h
        x = x + mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x), nsite, cfg.act)
        return constrain(x, "batch", None, None), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["ln_enc"], x)


def _decoder(cfg, params, y_embeds, positions, enc_out, kv_caches=None, cache_len=None):
    x = constrain(y_embeds, "batch", None, None)
    nsite = bind(cfg.numerics)

    def body(carry, scanned):
        x = carry
        if kv_caches is None:
            lp = scanned
            kv_slice = None
        else:
            lp, ck, cv = scanned
            kv_slice = (ck, cv)
        h, new_kv = attn_apply(
            lp["attn"], rmsnorm(lp["ln1"], x), nsite,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
            positions=positions, rope_theta=cfg.rope_theta,
            kv_cache=kv_slice, cache_len=cache_len, mask="causal",
        )
        x = x + h
        enc_kv = encode_cross_kv(
            lp["xattn"], enc_out, nsite, n_kv=cfg.n_kv, head_dim=cfg.hd
        )
        x = x + cross_attn_apply(
            lp["xattn"], rmsnorm(lp["ln_x"], x), enc_kv, nsite,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.hd,
        )
        x = x + mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x), nsite, cfg.act)
        x = constrain(x, "batch", None, None)
        return x, (None if kv_caches is None else new_kv)

    if kv_caches is None:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], *kv_caches))
    return rmsnorm(params["ln_dec"], x), new_caches


def train_loss(cfg: ModelConfig, params, batch):
    """batch: frames [B,S_src,Fd], tokens [B,S_tgt], labels [B,S_tgt]."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    y = params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    hidden, _ = _decoder(cfg, params, y, positions, enc_out)
    # reuse the chunked CE via a dense-LM-compatible view
    from repro.core.dense import dense

    import dataclasses
    cfg_lm = dataclasses.replace(cfg, tie_embeddings=False)
    return lm_loss_chunked(cfg_lm, {"unembed": params["unembed"]}, hidden, batch["labels"])


def kv_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.dec_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill(cfg: ModelConfig, params, frames, tokens, kv_caches):
    enc_out = encode(cfg, params, frames)
    b, s = tokens.shape
    y = params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    hidden, new_caches = _decoder(
        cfg, params, y, positions, enc_out, kv_caches=kv_caches, cache_len=jnp.int32(0)
    )
    from repro.core.dense import dense

    head_cfg = site_for(cfg.numerics, "lm_head")
    logits = dense(hidden[:, -1:, :], params["unembed"], head_cfg)
    return logits, new_caches


def decode_step(cfg: ModelConfig, params, token, enc_out, kv_caches, cache_len):
    b = token.shape[0]
    y = params["embed"][token].astype(jnp.dtype(cfg.act_dtype))
    positions = jnp.broadcast_to(cache_len + jnp.zeros((b, 1), jnp.int32), (b, 1))
    hidden, new_caches = _decoder(
        cfg, params, y, positions, enc_out, kv_caches=kv_caches, cache_len=cache_len
    )
    from repro.core.dense import dense

    head_cfg = site_for(cfg.numerics, "lm_head")
    logits = dense(hidden, params["unembed"], head_cfg)
    return logits, new_caches
