"""Grouped-query attention with KV cache, numerics-aware projections.

The attention core (QK^T, AV) runs in bf16/f32 on the MXU; the paper's
PLAM applies to the *linear layers* (as in its DNN experiments), which
route through ``repro.core.dense``.  Softmax is f32.

Numerics flow per-site: the q/k/v projections resolve the ``attn.qkv``
role, the output projection ``attn.out``, and enc-dec cross-attention
``attn.cross.*`` — so a :class:`~repro.core.policy.NumericsPolicy` can
run exact-posit attention under PLAM MLPs (or any other mix).  A plain
:class:`NumericsConfig` still applies uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense, dense_init
from repro.core.policy import SiteNumerics, site

from .common import apply_rope, causal_mask


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d, n_kv * head_dim, dtype),
        "wv": dense_init(k3, d, n_kv * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d, dtype),
    }


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def attn_core(q, k, v, mask, softcap=None):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,Kv,hd]; mask: [Sq,Sk] or [B,1,Sq,Sk]."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    logits *= hd ** -0.5
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask.ndim == 2:
        mask_b = mask[None, None, None, :, :]
    else:
        mask_b = mask[:, :, None, :, :] if mask.ndim == 4 else mask
    logits = jnp.where(mask_b, logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, sq, h, hd)


def attn_core_blockwise(q, k, v, *, causal: bool, block: int, softcap=None):
    """Flash-style blockwise attention (training/prefill path).

    Scans KV blocks with a running (max, sum, acc) online softmax, so
    the [Sq, Sk] score matrix is never materialized in HBM — one block
    of scores lives at a time (VMEM-sized on TPU).  Exact same math as
    `attn_core` (tested to ~1e-6).
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = (q.reshape(b, sq, kv, group, hd).astype(jnp.float32)) * hd ** -0.5
    sk = k.shape[1]
    block = min(block, sk)
    assert sk % block == 0, (sk, block)
    nb = sk // block
    kb = k.astype(jnp.float32).reshape(b, nb, block, kv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.astype(jnp.float32).reshape(b, nb, block, kv, hd).transpose(1, 0, 2, 3, 4)

    q_idx = jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry  # running max, normalizer, accumulator
        kc, vc, blk = inp  # [B, block, kv, hd] x2, block index
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            k_idx = blk * block + jnp.arange(block)
            msk = k_idx[None, :] <= q_idx[:, None]  # [sq, block]
            s = jnp.where(msk[None, None, None, :, :], s, jnp.float32(-1e30))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv, group, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, jnp.arange(nb)))
    out = acc / l[..., None]
    # [B,kv,g,Sq,hd] -> [B,Sq,H,hd]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attn_apply(
    p,
    x,
    ncfg: SiteNumerics,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions,
    rope_theta: float = 10_000.0,
    mrope_sections=None,
    kv_cache=None,
    cache_len=None,
    mask: str | jnp.ndarray = "causal",
    softcap=None,
    flash_block: int = 0,
):
    """Returns (out [B,S,d], new_kv) where new_kv is the updated cache
    (if one was passed) or the fresh (k, v) tensors."""
    b, s, _ = x.shape
    qkv_cfg = site(ncfg, "attn.qkv")
    q = _split_heads(dense(x, p["wq"], qkv_cfg), n_heads, head_dim)
    k = _split_heads(dense(x, p["wk"], qkv_cfg), n_kv, head_dim)
    v = _split_heads(dense(x, p["wv"], qkv_cfg), n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta, mrope_sections)
    k = apply_rope(k, positions, rope_theta, mrope_sections)

    if kv_cache is not None:
        # decode / chunked prefill / speculative verify: write the span
        # at cache_len, attend causally over the cache prefix.
        # cache_len is a scalar (one shared offset: legacy decode,
        # single-request chunked prefill) or a [B] vector (paged
        # multi-token scoring — every slot sits at its own offset).
        ck, cv = kv_cache
        if jnp.ndim(cache_len) == 0:
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, cache_len, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, cache_len, 0, 0))
            s_k = ck.shape[1]
            ki = jnp.arange(s_k)[None, :]
            qi = cache_len + jnp.arange(s)[:, None]
            m = ki <= qi  # causal over the cache prefix
        else:
            upd = jax.vmap(
                lambda c, u, o: jax.lax.dynamic_update_slice(c, u, (o, 0, 0)))
            ck = upd(ck, k.astype(ck.dtype), cache_len)
            cv = upd(cv, v.astype(cv.dtype), cache_len)
            s_k = ck.shape[1]
            ki = jnp.arange(s_k)[None, None, :]
            qi = cache_len[:, None] + jnp.arange(s)[None, :]  # [B, Sq]
            m = (ki <= qi[:, :, None])[:, None]  # [B, 1, Sq, Sk]
        out = attn_core(q, ck, cv, m, softcap)
        new_kv = (ck, cv)
    else:
        if flash_block and isinstance(mask, str) and s % flash_block == 0:
            out = attn_core_blockwise(
                q, k, v, causal=(mask == "causal"), block=flash_block, softcap=softcap)
        else:
            if isinstance(mask, str):
                m = causal_mask(s, s) if mask == "causal" else jnp.ones((s, s), bool)
            else:
                m = mask
            out = attn_core(q, k, v, m, softcap)
        new_kv = (k, v)

    out = dense(out.reshape(b, s, n_heads * head_dim), p["wo"], site(ncfg, "attn.out"))
    return out, new_kv


def attn_apply_paged(
    p,
    x,
    ncfg: SiteNumerics,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    lengths,
    k_pages,
    v_pages,
    block_tables,
    rope_theta: float = 10_000.0,
    mrope_sections=None,
    softcap=None,
    use_kernel=None,
):
    """Single-token decode attention over a paged KV cache.

    x: [B, 1, d]; k_pages/v_pages: [num_blocks, block_size, kv, hd]
    pool slices for this layer; block_tables: [B, max_blk] pool indices;
    lengths: [B] tokens already cached per sequence.  The new token's
    K/V are scattered into each sequence's current tail block, then
    attention reads through the block table (`repro.kernels`).  Returns
    (out [B, 1, d], (k_pages, v_pages)) with the pools updated.

    Numerics: the q/k/v/o projections route through `repro.core.dense`,
    so posit/PLAM multipliers stay live in serving exactly as in the
    monolithic path; the attention core is f32 on gathered pages.

    Sharding: under an active TP mesh the projections follow the
    Megatron column/row rules (q/k/v sharded by head), the pool scatter
    stays shard-local when the pool is kv-head sharded, and
    `paged_decode_attention` dispatches to its head-sharded shard_map
    path (or lets GSPMD partition the gather path for GQA kv < tp).
    """
    from repro.kernels.decode_attention import paged_decode_attention

    from .common import decode_positions

    b, s, _ = x.shape
    assert s == 1, "paged attention is a single-token decode path"
    if softcap is not None:  # softcap models use the monolithic path
        raise NotImplementedError("paged decode does not support logit softcap")
    block_size = k_pages.shape[1]
    qkv_cfg = site(ncfg, "attn.qkv")
    q = _split_heads(dense(x, p["wq"], qkv_cfg), n_heads, head_dim)
    k = _split_heads(dense(x, p["wk"], qkv_cfg), n_kv, head_dim)
    v = _split_heads(dense(x, p["wv"], qkv_cfg), n_kv, head_dim)
    positions = decode_positions(lengths, mrope=mrope_sections is not None)
    q = apply_rope(q, positions, rope_theta, mrope_sections)
    k = apply_rope(k, positions, rope_theta, mrope_sections)

    # scatter the new token into each sequence's tail block
    bidx = jnp.arange(b)
    blk = block_tables[bidx, lengths // block_size]  # [B]
    slot = lengths % block_size
    k_pages = k_pages.at[blk, slot].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[blk, slot].set(v[:, 0].astype(v_pages.dtype))

    out = paged_decode_attention(
        q[:, 0], k_pages, v_pages, block_tables, lengths + 1,
        use_kernel=use_kernel)
    out = dense(out.reshape(b, 1, n_heads * head_dim), p["wo"], site(ncfg, "attn.out"))
    return out, (k_pages, v_pages)


def cross_attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype=jnp.float32):
    return attn_init(key, d, n_heads, n_kv, head_dim, dtype)


def cross_attn_apply(p, x, enc_kv, ncfg: SiteNumerics, *, n_heads, n_kv, head_dim):
    """Decoder cross-attention over precomputed encoder (k, v)."""
    b, s, _ = x.shape
    qkv_cfg = site(ncfg, "attn.cross.qkv")
    q = _split_heads(dense(x, p["wq"], qkv_cfg), n_heads, head_dim)
    k, v = enc_kv
    m = jnp.ones((s, k.shape[1]), bool)
    out = attn_core(q, k, v, m)
    out_cfg = site(ncfg, "attn.cross.out")
    return dense(out.reshape(b, s, n_heads * head_dim), p["wo"], out_cfg)


def encode_cross_kv(p, enc_out, ncfg: SiteNumerics, *, n_kv, head_dim):
    qkv_cfg = site(ncfg, "attn.cross.qkv")
    k = _split_heads(dense(enc_out, p["wk"], qkv_cfg), n_kv, head_dim)
    v = _split_heads(dense(enc_out, p["wv"], qkv_cfg), n_kv, head_dim)
    return k, v
