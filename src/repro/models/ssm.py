"""Mamba2 (State Space Duality) block — TPU-native chunked SSD.

The SSD recurrence  S_t = a_t S_{t-1} + dt_t (B_t ⊗ x_t),
y_t = C_t^T S_t + D x_t  is evaluated with the chunked algorithm of the
Mamba2 paper: within a chunk the contribution is a (masked, decayed)
attention-like matmul (MXU-friendly); across chunks a short lax.scan
carries the [ds, hd] state.  This is the TPU adaptation: the quadratic
intra-chunk term rides the MXU, and the sequential part is S/Q steps
instead of S.

Single-token decode uses the O(1) recurrence directly.

Numerics sites: the input projection is ``ssm.proj.in``, the output
projection ``ssm.proj.out`` (the conv and state recurrence stay exact —
PLAM applies to the linear layers, as in the paper's DNN experiments).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dense import dense, dense_init
from repro.core.policy import SiteNumerics, site

from .common import rmsnorm, rmsnorm_init


def mamba2_dims(d_model: int, expand: int, head_dim: int, d_state: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    return d_inner, n_heads


def mamba2_init(key, d_model: int, *, expand: int, head_dim: int, d_state: int, d_conv: int, dtype=jnp.float32):
    di, nh = mamba2_dims(d_model, expand, head_dim, d_state)
    conv_dim = di + 2 * d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (ds), C (ds), dt (nh)]
        "in_proj": dense_init(k1, d_model, 2 * di + 2 * d_state + nh, dtype),
        "conv_w": (jax.random.normal(k2, (d_conv, conv_dim), jnp.float32) * (d_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),  # softplus(-2) ~ 0.13
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(k3, di, d_model, dtype),
    }


def _causal_dwconv(x, w, b):
    """Depthwise causal conv1d.  x: [B,S,C], w: [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # [K, 1, C]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(xh, bs, cs, dt, a_log, chunk: int):
    """Chunked SSD scan.

    xh: [B,S,H,hd] (head-split inner activations)
    bs, cs: [B,S,ds] (shared across heads, ngroups=1)
    dt: [B,S,H] f32 (post-softplus)
    returns y: [B,S,H,hd], final state [B,H,ds,hd]
    """
    b, s, h, hd = xh.shape
    ds = bs.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # dt=0 padding is exact: a = exp(0) = 1 preserves the state and
        # dt*x = 0 adds nothing; padded outputs are sliced off below.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bs = jnp.pad(bs, ((0, 0), (0, pad), (0, 0)))
        cs = jnp.pad(cs, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc = s_pad // q

    loga = (-jnp.exp(a_log)[None, None, :] * dt).astype(jnp.float32)  # [B,S,H] log a_t
    dtx = (xh.astype(jnp.float32) * dt[..., None]).astype(jnp.float32)

    def r(x, tail):  # [B,S_pad,...] -> [nc, B, q, ...]
        return x.reshape(b, nc, q, *tail).transpose(1, 0, 2, *range(3, 3 + len(tail)))

    la_c = r(loga, (h,))           # [nc,B,q,H]
    dtx_c = r(dtx, (h, hd))        # [nc,B,q,H,hd]
    b_c = r(bs.astype(jnp.float32), (ds,))  # [nc,B,q,ds]
    c_c = r(cs.astype(jnp.float32), (ds,))

    cum = jnp.cumsum(la_c, axis=2)  # inclusive cumsum of log a within chunk

    # intra-chunk: masked decayed attention-like term
    g = jnp.einsum("nbqs,nbks->nbqk", c_c, b_c)  # [nc,B,q,q]
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [nc,B,q,k,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(dec), 0.0)
    y_intra = jnp.einsum("nbqk,nbqkh,nbkhd->nbqhd", g, m, dtx_c)

    # chunk summaries: state contribution of each chunk
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from pos k to chunk end
    s_chunk = jnp.einsum("nbks,nbkh,nbkhd->nbhsd", b_c, dec_end, dtx_c)  # [nc,B,H,ds,hd]
    a_chunk = jnp.exp(cum[:, :, -1, :])  # [nc,B,H] total chunk decay

    def step(hstate, inp):
        s_c, a_c, c_blk, cum_blk = inp
        # y_inter from the carried state
        dec_in = jnp.exp(cum_blk)  # [B,q,H] decay from chunk start to pos q
        y_int = jnp.einsum("bqs,bhsd,bqh->bqhd", c_blk, hstate, dec_in)
        hnew = a_c[..., None, None] * hstate + s_c
        return hnew, y_int

    h0 = jnp.zeros((b, h, ds, hd), jnp.float32)
    hfin, y_inter = jax.lax.scan(step, h0, (s_chunk, a_chunk, c_c, cum))

    y = y_intra + y_inter  # [nc,B,q,H,hd]
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, hd)
    return y[:, :s], hfin


def mamba2_apply(
    p,
    x,
    ncfg: SiteNumerics,
    *,
    expand: int,
    head_dim: int,
    d_state: int,
    chunk: int,
    cache=None,
):
    """x: [B,S,d].  Training/prefill when cache is None; otherwise a
    single-token decode step with cache = {"h": [B,H,ds,hd],
    "conv": [B,K-1,conv_dim]}.  Returns (out, new_cache_or_final_state).
    """
    bsz, s, d_model = x.shape
    di, nh = mamba2_dims(d_model, expand, head_dim, d_state)
    proj = dense(x, p["in_proj"], site(ncfg, "ssm.proj.in"))
    z, xin, bsv, csv, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + d_state, 2 * di + 2 * d_state], axis=-1
    )
    conv_in = jnp.concatenate([xin, bsv, csv], axis=-1)

    # Single-token recurrence only when decoding (s == 1 with a cache);
    # prefill (s > 1) always runs the chunked scan from a fresh state.
    decode_1 = cache is not None and s == 1
    if not decode_1:
        conv_out = _causal_dwconv(conv_in, p["conv_w"], p["conv_b"])
        conv_tail = conv_in[:, -(p["conv_w"].shape[0] - 1):, :]
    else:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B,K,cd]
        conv_out = jnp.einsum(
            "bkc,kc->bc", hist.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        )[:, None, :] + p["conv_b"].astype(jnp.float32)
        conv_out = conv_out.astype(x.dtype)
        conv_tail = hist[:, 1:, :]

    conv_out = jax.nn.silu(conv_out)
    xc, bc, cc = jnp.split(conv_out, [di, di + d_state], axis=-1)
    xh = xc.reshape(bsz, -1, nh, head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if not decode_1:
        y, hfin = _ssd_chunked(xh, bc, cc, dt, p["A_log"], chunk)
    else:
        # O(1) single-step recurrence
        a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt[:, 0, :])  # [B,H]
        dbx = jnp.einsum(
            "bs,bhd->bhsd", bc[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None],
        )
        hfin = a[..., None, None] * cache["h"] + dbx
        y = jnp.einsum("bs,bhsd->bhd", cc[:, 0].astype(jnp.float32), hfin)[:, None]

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(y, p["out_proj"], site(ncfg, "ssm.proj.out"))
    new_cache = {"h": hfin, "conv": conv_tail}
    return out, new_cache


def mamba2_cache_init(batch: int, d_model: int, *, expand: int, head_dim: int, d_state: int, d_conv: int, dtype=jnp.float32):
    di, nh = mamba2_dims(d_model, expand, head_dim, d_state)
    return {
        "h": jnp.zeros((batch, nh, d_state, head_dim), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, di + 2 * d_state), dtype),
    }
