"""Decoder-only transformer LM family (dense / MoE / VLM backbones).

One scan-over-layers implementation covers minitron-8b, yi-6b,
command-r-plus-104b, gemma-7b, granite-moe, deepseek-moe and the
qwen2-vl backbone — all differences are config-driven (GQA widths,
GeGLU, MoE, M-RoPE, embedding scaling, head_dim overrides).

Layer parameters are stacked on a leading [L] axis and scanned, keeping
the HLO small enough to compile 80-layer models against a 512-device
mesh.  `remat` wraps the layer body in jax.checkpoint.

Numerics: every matmul resolves a *site* (``attn.qkv``, ``mlp.down``,
``lm_head``, ...) against ``cfg.numerics`` — a uniform
:class:`NumericsConfig` or a per-site :class:`NumericsPolicy`.  Layer-
range policy rules split the scan into policy-uniform segments (a
single ``lax.scan`` cannot vary static numerics per step); uniform
policies keep the original single scan, bit-identically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dense import dense, dense_init
from repro.core.policy import site_for
from repro.parallel.sharding import constrain

from .attention import attn_apply, attn_apply_paged, attn_init
from .common import (
    embed_init,
    multi_token_positions,
    rmsnorm,
    rmsnorm_init,
    scan_policy_segments,
    stack_layer_params,
)
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init


def layer_init(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(
            k2, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
            cfg.n_shared_experts, cfg.moe_d_ff, cfg.glu, dtype,
        )
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def lm_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ku = jax.random.split(key, 3)
    params = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stack_layer_params(partial(layer_init, cfg, dtype=dtype), kl, cfg.n_layers),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ku, cfg.d_model, cfg.vocab, dtype)
    return params


def _ffn_fwd(cfg: ModelConfig, nsite, p, hn):
    """The post-attention half of a block (MoE or dense MLP)."""
    if cfg.n_experts:
        return moe_apply(
            p["moe"], hn, nsite,
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, act=cfg.act,
            groups=cfg.moe_groups,
        )
    return mlp_apply(p["mlp"], hn, nsite, cfg.act)


def _layer_fwd(cfg: ModelConfig, nsite, p, x, positions, kv_slice, cache_len):
    """One transformer block.  kv_slice None for training (full-seq).

    nsite: per-segment site numerics (a NumericsConfig or BoundPolicy).
    """
    h, new_kv = attn_apply(
        p["attn"],
        rmsnorm(p["ln1"], x),
        nsite,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.hd,
        positions=positions,
        rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections,
        kv_cache=kv_slice,
        cache_len=cache_len,
        softcap=cfg.attn_logit_softcap,
        flash_block=cfg.flash_block,
    )
    x = x + h
    h2 = _ffn_fwd(cfg, nsite, p, rmsnorm(p["ln2"], x))
    x = x + h2
    x = constrain(x, "batch", None, None)
    return x, new_kv


def _scan_layers(cfg: ModelConfig, nsite, layer_params, x, positions,
                 kv_caches, cache_len):
    """One lax.scan over a policy-uniform run of stacked layers."""

    def body(carry, scanned):
        x = carry
        if kv_caches is None:
            lp = scanned
            fn = partial(_layer_fwd, cfg, nsite)
            if cfg.remat:
                fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = fn(lp, x, positions, None, None)
            return x, None
        lp, ck, cv = scanned
        x, (nk, nv) = _layer_fwd(cfg, nsite, lp, x, positions, (ck, cv), cache_len)
        return x, (nk, nv)

    if kv_caches is None:
        x, _ = jax.lax.scan(body, x, layer_params)
        return x, None
    return jax.lax.scan(body, x, (layer_params, *kv_caches))


def lm_backbone(cfg: ModelConfig, params, embeds, positions, kv_caches=None, cache_len=None):
    """Scan the stacked layers.  Returns (hidden, new_kv_caches).

    kv_caches: None for training, else (k[L,B,S,kv,hd], v[L,...]).
    Layer-range numerics rules split the stack into segments, each
    scanned under its own resolved configs; a layer-uniform policy is a
    single segment — the exact original scan.
    """
    x = embeds
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x, "batch", None, None)

    def scan_segment(x, layer_params, seg_caches, nsite):
        return _scan_layers(
            cfg, nsite, layer_params, x, positions, seg_caches, cache_len
        )

    x, new_caches = scan_policy_segments(
        cfg.numerics, cfg.n_layers, params["layers"], kv_caches, x, scan_segment
    )
    x = rmsnorm(params["ln_f"], x)
    return x, new_caches


def lm_logits(cfg: ModelConfig, params, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    head_cfg = site_for(cfg.numerics, "lm_head", n_layers=cfg.n_layers)
    if jnp.issubdtype(w.dtype, jnp.integer):  # prequantized lm_head patterns
        logits = dense(hidden, w, head_cfg)
    else:
        logits = dense(hidden, w.astype(hidden.dtype), head_cfg)
    return constrain(logits, "batch", None, "model")


def lm_loss_chunked(cfg: ModelConfig, params, hidden, labels, chunk: int = 512):
    """Cross-entropy without materializing [B,S,V] at once.

    Scans sequence chunks; each chunk's logits are formed, reduced, and
    discarded (rematerialized in backward).  Keeps peak logits memory at
    B * chunk * V.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    valid = (labels >= 0).astype(jnp.float32)  # label -1 == masked position
    labels = jnp.maximum(labels, 0)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    vc = valid.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, l, v):
        logits = lm_logits(cfg, params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * v)

    def body(acc, xs):
        h, l, v = xs
        return acc + chunk_loss(h, l, v), None

    tot, _ = jax.lax.scan(body, jnp.float32(0), (hc, lc, vc))
    return tot / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# public entry points used by the launcher / serving engine
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens):
    return params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))


def default_positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope_sections:  # text-only M-RoPE: all three sections equal
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def train_loss(cfg: ModelConfig, params, batch):
    """batch: {tokens [B,S], labels [B,S], (optional) embeds_prefix}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if "embeds_prefix" in batch:  # VLM: precomputed patch embeddings
        x = jnp.concatenate([batch["embeds_prefix"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        # patch positions carry no next-token target: mask with -1
        labels = jnp.pad(
            batch["labels"], ((0, 0), (s - tokens.shape[1], 0)), constant_values=-1
        )
    else:
        labels = batch["labels"]
    positions = default_positions(cfg, b, s)
    hidden, _ = lm_backbone(cfg, params, x, positions)
    return lm_loss_chunked(cfg, params, hidden, labels)


def kv_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill(cfg: ModelConfig, params, tokens, kv_caches):
    """Full-sequence prefill writing the KV cache; returns last logits."""
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = default_positions(cfg, b, s)
    hidden, new_caches = lm_backbone(
        cfg, params, x, positions, kv_caches=kv_caches, cache_len=jnp.int32(0)
    )
    logits = lm_logits(cfg, params, hidden[:, -1:, :])
    return logits, new_caches


def decode_step(cfg: ModelConfig, params, token, kv_caches, cache_len):
    """One-token decode.  token: [B,1]; cache_len: traced int32."""
    b = token.shape[0]
    x = embed_tokens(cfg, params, token)
    positions = default_positions(cfg, b, 1, offset=cache_len)
    hidden, new_caches = lm_backbone(
        cfg, params, x, positions, kv_caches=kv_caches, cache_len=cache_len
    )
    logits = lm_logits(cfg, params, hidden)
    return logits, new_caches


# ---------------------------------------------------------------------------
# paged KV cache path (continuous-batching serving)
# ---------------------------------------------------------------------------

def paged_kv_pool_init(cfg: ModelConfig, num_blocks: int, block_size: int,
                       dtype=jnp.bfloat16):
    """Block-pool KV storage shared by ALL sequences: two arrays of
    shape [L, num_blocks, block_size, kv, hd].  Sequences own disjoint
    sets of blocks, named by their block tables (`repro.serving`)."""
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv, cfg.hd)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def paged_prefill(cfg: ModelConfig, params, tokens, k_pool, v_pool,
                  block_ids, true_len):
    """Prefill ONE request into pool blocks.

    tokens: [1, S_pad] right-padded to a block-size multiple;
    block_ids: [S_pad / block_size] pool blocks owned by this request;
    true_len: traced int32 — real prompt length (padding past it is
    causal-masked out of the returned logits and overwritten by decode
    before it is ever attendable).  Returns (logits [1, 1, V] at the
    last real token, updated (k_pool, v_pool)).
    """
    b, s = tokens.shape
    assert b == 1, "paged prefill admits one request at a time"
    block_size = k_pool.shape[2]
    nb = block_ids.shape[0]
    assert s == nb * block_size, (s, nb, block_size)
    caches = kv_cache_init(cfg, b, s, k_pool.dtype)
    x = embed_tokens(cfg, params, tokens)
    positions = default_positions(cfg, b, s)
    hidden, (ck, cv) = lm_backbone(
        cfg, params, x, positions, kv_caches=caches, cache_len=jnp.int32(0))
    kv_shape = (cfg.n_layers, nb, block_size, cfg.n_kv, cfg.hd)
    k_pool = k_pool.at[:, block_ids].set(ck[:, 0].reshape(kv_shape))
    v_pool = v_pool.at[:, block_ids].set(cv[:, 0].reshape(kv_shape))
    last = jax.lax.dynamic_slice_in_dim(hidden, true_len - 1, 1, axis=1)
    logits = lm_logits(cfg, params, last)
    return logits, (k_pool, v_pool)


def _paged_gather_forward(cfg: ModelConfig, params, tokens, k_pool, v_pool,
                          block_tables, lengths):
    """Shared gather→attend→scatter machinery for every multi-token
    paged path (chunked prefill, speculative verify).

    tokens: [B, W] token span per slot; block_tables: [B, max_blk] full
    table rows (scratch-padded, static width so every call shares one
    compile); lengths: [B] traced int32 tokens already cached per slot
    — token j of slot b is written at position ``lengths[b] + j``.

    Each slot's blocks are gathered into a contiguous [L,B,S,kv,hd]
    cache, the span runs through the same dynamic-update + causal-mask
    attention as single-token decode (`attn_apply` kv_cache path, with
    per-slot offsets), and the updated cache is scattered back to the
    pool.  Writes land inside each slot's owned blocks as long as
    ``lengths[b] + W <= capacity`` — the scheduler's worst-case burst
    reservation guarantees it for live slots; idle/scratch slots write
    only the reserved scratch block 0, which no live mask ever admits.
    Returns (hidden [B, W, d], updated (k_pool, v_pool)).
    """
    b, w = tokens.shape
    nl, _, block_size, n_kv, hd = k_pool.shape
    nb = block_tables.shape[1]
    s = nb * block_size
    flat = block_tables.reshape(-1)
    ck = k_pool[:, flat].reshape(nl, b, s, n_kv, hd)
    cv = v_pool[:, flat].reshape(nl, b, s, n_kv, hd)
    x = embed_tokens(cfg, params, tokens)
    positions = multi_token_positions(
        lengths, w, mrope=cfg.mrope_sections is not None)
    hidden, (ck, cv) = lm_backbone(
        cfg, params, x, positions, kv_caches=(ck, cv), cache_len=lengths)
    kv_shape = (nl, b * nb, block_size, n_kv, hd)
    k_pool = k_pool.at[:, flat].set(ck.reshape(kv_shape))
    v_pool = v_pool.at[:, flat].set(cv.reshape(kv_shape))
    return hidden, (k_pool, v_pool)


def paged_prefill_chunk(cfg: ModelConfig, params, tokens, k_pool, v_pool,
                        block_ids, cache_len, last_idx):
    """Prefill ONE chunk of one request through the incremental path.

    tokens: [1, C] — C is the engine's fixed chunk width (a block-size
    multiple; the ragged final chunk is right-padded to a block
    multiple).  block_ids: [max_blk] the request's full block-table row
    (scratch-padded); cache_len: traced int32 prompt tokens already
    cached; last_idx: traced int32 chunk-local index of the last REAL
    token (only meaningful on the final chunk, where its logits seed
    decoding).

    Thin wrapper over `_paged_gather_forward` (B=1): padding past the
    real tokens lands beyond `cache_len + real` where the causal mask
    never reads it before decode overwrites it.  Returns
    (logits [1, 1, V] at last_idx, updated (k_pool, v_pool)).
    """
    assert tokens.shape[0] == 1, "chunked prefill admits one request at a time"
    hidden, pools = _paged_gather_forward(
        cfg, params, tokens, k_pool, v_pool, block_ids[None, :],
        jnp.reshape(cache_len, (1,)))
    last = jax.lax.dynamic_slice_in_dim(hidden, last_idx, 1, axis=1)
    logits = lm_logits(cfg, params, last)
    return logits, pools


def paged_score_tokens(cfg: ModelConfig, params, tokens, k_pool, v_pool,
                       block_tables, lengths):
    """Score a W-token span per slot in ONE batched call (the
    speculative-decoding verify step).

    tokens: [B, W] — token 0 is each slot's last sampled-but-uncached
    token, tokens 1..W-1 the drafted continuation; block_tables:
    [B, max_blk]; lengths: [B] committed cache length per slot.  Writes
    K/V for all W tokens at positions lengths..lengths+W-1 (the engine
    rolls the logical length back over rejected tails afterwards) and
    returns (logits [B, W, V], updated pools) — logits[:, j] is the
    target distribution for the token AFTER tokens[:, j], so a greedy
    acceptance scan over argmax(logits) reproduces sequential decode
    exactly.
    """
    hidden, pools = _paged_gather_forward(
        cfg, params, tokens, k_pool, v_pool, block_tables, lengths)
    logits = lm_logits(cfg, params, hidden)
    return logits, pools


def paged_decode_step(cfg: ModelConfig, params, token, k_pool, v_pool,
                      block_tables, lengths, use_kernel=None):
    """One decode step for a heterogeneous batch over the paged cache.

    token: [B, 1] last token per slot; block_tables: [B, max_blk] pool
    indices (inactive slots point at the reserved scratch block 0);
    lengths: [B] per-sequence cached-token counts — each slot advances
    independently, which is what lets the engine admit and retire
    sequences every step.  Returns (logits [B, 1, V], new pools).
    """
    x = embed_tokens(cfg, params, token)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x, "batch", None, None)

    def scan_segment(x, layer_params, pools, nsite):
        def body(x, scanned):
            lp, ck, cv = scanned
            h, (nk, nv) = attn_apply_paged(
                lp["attn"],
                rmsnorm(lp["ln1"], x),
                nsite,
                n_heads=cfg.n_heads,
                n_kv=cfg.n_kv,
                head_dim=cfg.hd,
                lengths=lengths,
                k_pages=ck,
                v_pages=cv,
                block_tables=block_tables,
                rope_theta=cfg.rope_theta,
                mrope_sections=cfg.mrope_sections,
                softcap=cfg.attn_logit_softcap,
                use_kernel=use_kernel,
            )
            x = x + h
            h2 = _ffn_fwd(cfg, nsite, lp, rmsnorm(lp["ln2"], x))
            x = x + h2
            x = constrain(x, "batch", None, None)
            return x, (nk, nv)

        return jax.lax.scan(body, x, (layer_params, *pools))

    x, new_pools = scan_policy_segments(
        cfg.numerics, cfg.n_layers, params["layers"], (k_pool, v_pool), x, scan_segment
    )
    x = rmsnorm(params["ln_f"], x)
    logits = lm_logits(cfg, params, x)
    return logits, new_pools
