"""Decoder-only transformer LM family (dense / MoE / VLM backbones).

One scan-over-layers implementation covers minitron-8b, yi-6b,
command-r-plus-104b, gemma-7b, granite-moe, deepseek-moe and the
qwen2-vl backbone — all differences are config-driven (GQA widths,
GeGLU, MoE, M-RoPE, embedding scaling, head_dim overrides).

Layer parameters are stacked on a leading [L] axis and scanned, keeping
the HLO small enough to compile 80-layer models against a 512-device
mesh.  `remat` wraps the layer body in jax.checkpoint.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dense import dense, dense_init
from repro.parallel.sharding import constrain

from .attention import attn_apply, attn_init
from .common import embed_init, rmsnorm, rmsnorm_init, stack_layer_params
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init


def layer_init(cfg: ModelConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(
            k2, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
            cfg.n_shared_experts, cfg.moe_d_ff, cfg.glu, dtype,
        )
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def lm_init(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, ku = jax.random.split(key, 3)
    params = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stack_layer_params(partial(layer_init, cfg, dtype=dtype), kl, cfg.n_layers),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ku, cfg.d_model, cfg.vocab, dtype)
    return params


def _layer_fwd(cfg: ModelConfig, p, x, positions, kv_slice, cache_len):
    """One transformer block.  kv_slice None for training (full-seq)."""
    h, new_kv = attn_apply(
        p["attn"],
        rmsnorm(p["ln1"], x),
        cfg.numerics,
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.hd,
        positions=positions,
        rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections,
        kv_cache=kv_slice,
        cache_len=cache_len,
        softcap=cfg.attn_logit_softcap,
        flash_block=cfg.flash_block,
    )
    x = x + h
    hn = rmsnorm(p["ln2"], x)
    if cfg.n_experts:
        h2 = moe_apply(
            p["moe"], hn, cfg.numerics,
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, act=cfg.act,
            groups=cfg.moe_groups,
        )
    else:
        h2 = mlp_apply(p["mlp"], hn, cfg.numerics, cfg.act)
    x = x + h2
    x = constrain(x, "batch", None, None)
    return x, new_kv


def lm_backbone(cfg: ModelConfig, params, embeds, positions, kv_caches=None, cache_len=None):
    """Scan the stacked layers.  Returns (hidden, new_kv_caches).

    kv_caches: None for training, else (k[L,B,S,kv,hd], v[L,...]).
    """
    x = embeds
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = constrain(x, "batch", None, None)

    def body(carry, scanned):
        x = carry
        if kv_caches is None:
            lp = scanned
            fn = partial(_layer_fwd, cfg)
            if cfg.remat:
                fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = fn(lp, x, positions, None, None)
            return x, None
        lp, ck, cv = scanned
        x, (nk, nv) = _layer_fwd(cfg, lp, x, positions, (ck, cv), cache_len)
        return x, (nk, nv)

    if kv_caches is None:
        x, _ = jax.lax.scan(body, x, params["layers"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], *kv_caches))
    x = rmsnorm(params["ln_f"], x)
    return x, new_caches


def lm_logits(cfg: ModelConfig, params, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = dense(hidden, w.astype(hidden.dtype), cfg.numerics)
    return constrain(logits, "batch", None, "model")


def lm_loss_chunked(cfg: ModelConfig, params, hidden, labels, chunk: int = 512):
    """Cross-entropy without materializing [B,S,V] at once.

    Scans sequence chunks; each chunk's logits are formed, reduced, and
    discarded (rematerialized in backward).  Keeps peak logits memory at
    B * chunk * V.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    valid = (labels >= 0).astype(jnp.float32)  # label -1 == masked position
    labels = jnp.maximum(labels, 0)
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    vc = valid.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, l, v):
        logits = lm_logits(cfg, params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * v)

    def body(acc, xs):
        h, l, v = xs
        return acc + chunk_loss(h, l, v), None

    tot, _ = jax.lax.scan(body, jnp.float32(0), (hc, lc, vc))
    return tot / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# public entry points used by the launcher / serving engine
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens):
    return params["embed"][tokens].astype(jnp.dtype(cfg.act_dtype))


def default_positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope_sections:  # text-only M-RoPE: all three sections equal
        pos = jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def train_loss(cfg: ModelConfig, params, batch):
    """batch: {tokens [B,S], labels [B,S], (optional) embeds_prefix}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if "embeds_prefix" in batch:  # VLM: precomputed patch embeddings
        x = jnp.concatenate([batch["embeds_prefix"].astype(x.dtype), x], axis=1)
        s = x.shape[1]
        # patch positions carry no next-token target: mask with -1
        labels = jnp.pad(
            batch["labels"], ((0, 0), (s - tokens.shape[1], 0)), constant_values=-1
        )
    else:
        labels = batch["labels"]
    positions = default_positions(cfg, b, s)
    hidden, _ = lm_backbone(cfg, params, x, positions)
    return lm_loss_chunked(cfg, params, hidden, labels)


def kv_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill(cfg: ModelConfig, params, tokens, kv_caches):
    """Full-sequence prefill writing the KV cache; returns last logits."""
    b, s = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = default_positions(cfg, b, s)
    hidden, new_caches = lm_backbone(
        cfg, params, x, positions, kv_caches=kv_caches, cache_len=jnp.int32(0)
    )
    logits = lm_logits(cfg, params, hidden[:, -1:, :])
    return logits, new_caches


def decode_step(cfg: ModelConfig, params, token, kv_caches, cache_len):
    """One-token decode.  token: [B,1]; cache_len: traced int32."""
    b = token.shape[0]
    x = embed_tokens(cfg, params, token)
    positions = default_positions(cfg, b, 1, offset=cache_len)
    hidden, new_caches = lm_backbone(
        cfg, params, x, positions, kv_caches=kv_caches, cache_len=cache_len
    )
    logits = lm_logits(cfg, params, hidden)
    return logits, new_caches
