"""Deterministic synthetic data pipeline.

Streams are *stateless*: batch contents are a pure function of
(step, shard) via threefry, so a restarted / re-sharded / elastic run
regenerates exactly the same global batch without any storage — the
skip-ahead needed for checkpoint-restart fault tolerance is free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 512
    seq_len: int = 128
    global_batch: int = 8


def lm_batch(cfg: DataConfig, step: int):
    """Global LM batch for `step`: tokens + next-token labels.

    A Markov-ish synthetic language: token t+1 depends on token t
    through a fixed random permutation plus noise, so a model can
    actually learn structure (loss decreases) — needed by the paper's
    accuracy-parity experiments and the train-loop tests.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    perm = jax.random.permutation(jax.random.PRNGKey(cfg.seed + 7), v)
    first = jax.random.randint(k1, (b, 1), 0, v)
    noise = jax.random.bernoulli(k2, 0.1, (b, s))
    rand = jax.random.randint(jax.random.fold_in(k2, 1), (b, s), 0, v)

    def step_fn(tok, inp):
        nz, rd = inp
        nxt = jnp.where(nz, rd, perm[tok])
        return nxt, nxt

    _, toks = jax.lax.scan(step_fn, first[:, 0], (noise.T, rand.T))
    tokens = jnp.concatenate([first, toks.T[:, :-1]], axis=1).astype(jnp.int32)
    labels = toks.T.astype(jnp.int32)
    return {"tokens": tokens, "labels": labels}


def classification_dataset(seed: int, n: int, d_in: int, n_classes: int, *, margin: float = 4.0):
    """Gaussian-cluster classification data (ISOLET/HAR stand-ins).

    Returns (x [n, d_in] f32, y [n] i32).  Class centers are random unit
    vectors scaled by `margin`; inputs add unit noise.
    """
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_classes, d_in)).astype(np.float32)
    centers *= margin / np.linalg.norm(centers, axis=1, keepdims=True)
    y = rng.integers(0, n_classes, n)
    x = centers[y] + rng.standard_normal((n, d_in)).astype(np.float32) * 0.8
    return x.astype(np.float32), y.astype(np.int32)


def image_dataset(seed: int, n: int, hw: int, channels: int, n_classes: int):
    """Synthetic image classification (MNIST/SVHN/CIFAR stand-ins):
    class-dependent frequency gratings + noise, [n, hw, hw, c]."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, n)
    xs = np.linspace(0, np.pi * 2, hw, dtype=np.float32)
    xx, yy = np.meshgrid(xs, xs)
    imgs = np.empty((n, hw, hw, channels), np.float32)
    for c in range(n_classes):
        idx = np.where(y == c)[0]
        freq = 1.0 + c * 0.25
        phase = rng.uniform(0, np.pi, (len(idx), 1, 1))
        base = np.sin(freq * xx)[None] + np.cos(freq * yy)[None] + phase
        for ch in range(channels):
            imgs[idx, :, :, ch] = base + rng.standard_normal((len(idx), hw, hw)) * 3.0
    return imgs * 0.25, y.astype(np.int32)
