import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any real arrays:
  * compiled.memory_analysis()  — proves the program fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective bytes parsed from the compiled HLO text
Results are appended to experiments/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

``--numerics-policy`` compiles the cells under a per-site numerics
policy (string or saved-artifact path); ``--numerics`` stays as the
single-mode sugar for ``default=<mode>``.
"""
import argparse
import json
import re
import time

import jax
from jax.sharding import NamedSharding

from repro.configs import ARCHS, applicable_shapes, get_config, shape_by_name
from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.policy import describe, load_policy_arg, parse_policy
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim.optimizers import OptConfig, init_state, state_shardings
from repro.parallel.sharding import (
    expert_parallel_rules,
    param_shardings,
    pspec,
    sanitize,
    use_mesh,
)
from repro.train.loop import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# input shardings
# ---------------------------------------------------------------------------

def batch_shardings(mesh, specs, *, seq_parallel: bool, kv_seq_tp: bool = False):
    """Logical shardings for a dry-run input batch, keyed on path names.

    kv_seq_tp: shard decode KV caches' sequence dim over the model axis
    (used when kv heads are not divisible by the TP degree).
    """

    def path_str(path):
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        return "/".join(parts)

    def one(path, leaf):
        ps = path_str(path)
        r = leaf.ndim
        if leaf.shape == ():
            dims = ()
        elif re.search(r"(tokens|labels|token)$", ps):
            dims = ("batch",) + (None,) * (r - 1)
        elif re.search(r"(embeds_prefix|frames)", ps):
            dims = ("batch", None, None)
        elif re.search(r"enc_out", ps):
            dims = (None, "seq", None) if seq_parallel else ("batch", None, None)
        elif re.search(r"(kv_caches|shared_k|shared_v)", ps) and r == 5:
            # [L, B, S, kv, hd]
            if seq_parallel:
                dims = (None, None, "seq", "model", None)
            elif kv_seq_tp:
                # GQA with kv heads < TP degree: shard the cache SEQ dim
                # over the model axis (flash-style partial-softmax combine)
                dims = (None, "batch", "seq_tp", None, None)
            else:
                dims = (None, "batch", None, "model", None)
        elif ps.endswith("/h") and r == 5:  # mamba state [L,B,H,ds,hd]
            dims = (None, "batch", "model", None, None)
        elif re.search(r"conv", ps) and r == 4:  # [L,B,K-1,C]
            dims = (None, "batch", None, "model")
        else:
            dims = (None,) * r
        dims = sanitize(mesh, dims, leaf.shape)
        return NamedSharding(mesh, pspec(mesh, dims))

    return jax.tree_util.tree_map_with_path(one, specs)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (step_fn, arg_specs, in_shardings) for one dry-run cell."""
    api = build(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    rules = expert_parallel_rules() if getattr(cfg, "expert_parallel", False) else None
    p_sh = param_shardings(mesh, params, rules)
    seq_par = shape.kind == "decode" and shape.global_batch == 1

    if shape.kind == "train":
        batch = api.train_inputs(shape)
        b_sh = batch_shardings(mesh, batch, seq_parallel=False)
        ocfg = OptConfig(name="adamw", lr=1e-4)
        opt = jax.eval_shape(lambda: init_state(ocfg, params))
        o_sh = state_shardings(ocfg, mesh, params, rules)
        step = make_train_step(api.train_loss, TrainConfig(opt=ocfg))
        return step, (params, opt, batch), (p_sh, o_sh, b_sh)

    if shape.kind == "prefill":
        batch = api.prefill_inputs(shape)
        b_sh = batch_shardings(mesh, batch, seq_parallel=False)

        def step(params, batch):
            return api.prefill(params, batch)

        return step, (params, batch), (p_sh, b_sh)

    # decode
    batch = api.decode_inputs(shape)
    kv_seq_tp = bool(getattr(cfg, "kv_seq_tp", False))
    b_sh = batch_shardings(mesh, batch, seq_parallel=seq_par, kv_seq_tp=kv_seq_tp)

    def step(params, batch):
        return api.decode_step(params, batch)

    return step, (params, batch), (p_sh, b_sh)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir="experiments/dryrun",
             cfg_override=None, tag=""):
    cfg = cfg_override or get_config(arch)
    shape = shape_by_name(shape_name)
    if shape not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": "quadratic attention at 524k (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh):
        step, args, shardings = build_cell(cfg, shape, mesh)
        jitted = jax.jit(step, in_shardings=shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        ana = analyze(hlo_text)  # trip-count-corrected

    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        # per-device, trip-count-corrected (see hlo_analysis.py)
        "flops": ana.flops,
        "elem_ops": ana.elem_ops,
        "bytes_accessed": ana.hbm_bytes,
        "collectives": ana.as_dict(),
        # raw XLA numbers (loop bodies counted once) kept for reference
        "xla_cost_flops": float(cost.get("flops", -1)),
        "xla_cost_bytes": float(cost.get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "numerics": describe(cfg.numerics),
        "tag": tag,
    }
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{rec['mesh']}{('__' + tag) if tag else ''}"
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    # archive the compiled HLO so the analyzer can be re-run offline
    import gzip

    hlo_dir = os.path.join(os.path.dirname(out_dir.rstrip("/")) or ".", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    with gzip.open(os.path.join(hlo_dir, stem + ".txt.gz"), "wt") as f:
        f.write(hlo_text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--numerics", default=None,
                    choices=["f32", "bf16", "posit_quant", "plam_sim", "mitchell_f32"],
                    help="uniform mode; sugar for --numerics-policy 'default=<mode>'")
    ap.add_argument("--numerics-policy", default=None,
                    help="per-site policy string or saved-artifact path")
    args = ap.parse_args()

    policy = None
    if args.numerics_policy is not None:
        policy = load_policy_arg(args.numerics_policy)
    elif args.numerics is not None:
        policy = parse_policy(f"default={args.numerics}")

    cells = []
    if args.all:
        from repro.configs.base import ALL_SHAPES

        for arch in ARCHS:
            for shape in ALL_SHAPES:  # inapplicable cells emit SKIP records
                cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))

    for arch, shape in cells:
        try:
            cfg_override = (
                get_config(arch).with_numerics(policy) if policy is not None else None
            )
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           out_dir=args.out_dir, cfg_override=cfg_override)
            status = "SKIP" if "skipped" in rec else "OK"
            print(f"[{status}] {arch} x {shape} ({'multi' if args.multi_pod else 'single'}): "
                  + (rec.get("skipped") or
                     f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
                     f"coll={rec['collectives']['collective_total']:.3e} compile={rec['compile_s']}s"),
                  flush=True)
        except Exception as e:  # noqa: BLE001 — a failing cell is a bug to surface
            print(f"[FAIL] {arch} x {shape}: {type(e).__name__}: {e}", flush=True)
            raise


if __name__ == "__main__":
    main()
