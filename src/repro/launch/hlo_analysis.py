"""Trip-count-corrected analysis of compiled HLO modules.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified empirically: scan(4) and scan(8) of the same matmul report
identical flops).  Layer-scanned models therefore undercount by ~L x.
This module parses ``compiled.as_text()`` into a computation call graph,
reads while trip counts from ``backend_config={"known_trip_count"...}``
(falling back to the loop-condition constant), and aggregates

  * dot/conv FLOPs            (exact, from operand/result shapes)
  * element-op counts         (VPU proxy: result elements of non-dot ops)
  * HBM byte traffic          (operand+result bytes of top-level ops —
                               the XLA fusion boundary is the HBM unit)
  * collective bytes by type  (result bytes of all-gather/all-reduce/
                               reduce-scatter/all-to-all/collective-permute)

each multiplied by the product of enclosing loop trip counts.
Validated against cost_analysis on loop-free programs in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "token": 0,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|c64|c128|s64|s32|s16|s8|s4|u64|u32|u16|u8|u4|pred|token)\[([0-9,]*)\]"
)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "while", "conditional",
             "call", "optimization-barrier", "domain"}


def _dims_of(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    return [(dt, _dims_of(dims)) for dt, dims in _SHAPE_RE.findall(text)]


def _nbytes(shapes) -> float:
    return float(sum(_DT_BYTES[dt] * math.prod(d) if d else _DT_BYTES[dt]
                     for dt, d in shapes))


def _nelems(shapes) -> float:
    return float(sum(math.prod(d) if d else 1 for _, d in shapes))


@dataclasses.dataclass
class OpLine:
    name: str
    opcode: str
    result: List[Tuple[str, List[int]]]  # result shape(s)
    operands: List[str]  # operand op names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpLine]
    by_name: Dict[str, OpLine]


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(r"^(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s([a-z][\w\-]*)\(")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    comps_entry: List[str] = []
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        stripped = raw.strip()
        if cur is None or not raw.startswith(" "):
            m = _HEADER_RE.match(stripped)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):  # explicit ENTRY marker
                    comps_entry.append(cur.name)
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(stripped)
        if not om:
            continue
        rhs = om.group(3)
        # opcode: first `word(` after the type annotation
        oc = _OPCODE_RE.search(" " + rhs)
        if not oc:
            continue
        opcode = oc.group(1)
        type_part = rhs[: rhs.find(opcode + "(")]
        args_m = re.search(rf"{opcode}\(([^)]*)\)", rhs)
        operands = []
        if args_m:
            args = args_m.group(1)
            # operand tokens are either typed ("f32[8,8]{1,0} %foo") or
            # bare ("foo") depending on the XLA version; typed shapes
            # embed commas, so prefer the unambiguous %name markers and
            # only comma-split when the bare format is in use
            operands = re.findall(r"%([\w\.\-]+)", args)
            if not operands:
                for tok in args.split(","):
                    nm = re.match(r"([\w\.\-]+)$", tok.strip())
                    if nm:
                        operands.append(nm.group(1))
        op = OpLine(om.group(2), opcode, _shape_list(type_part), operands, stripped)
        cur.ops.append(op)
        cur.by_name[op.name] = op
    return comps, comps_entry


def _called_comps(line: str):
    out = []
    for attr in ("body", "condition", "calls", "to_apply"):
        for m in re.finditer(rf"\b{attr}=%?([\w\.\-]+)", line):
            out.append((attr, m.group(1)))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", line):
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


def _while_trip(line: str, comps, pairs) -> float:
    m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', line)
    if m:
        return float(m.group(1))
    cond_name = next((n for a, n in pairs if a == "condition"), None)
    if cond_name and cond_name in comps:
        consts = []
        for op in comps[cond_name].ops:
            for c in re.finditer(r"constant\((\d+)\)", op.line):
                consts.append(int(c.group(1)))
        if consts:
            return float(max(consts))
    return 1.0


def _dot_flops(op: OpLine, comp: Computation) -> float:
    out_elems = _nelems(op.result)
    if op.opcode == "dot":
        k = 1.0
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        lhs = comp.by_name.get(op.operands[0]) if op.operands else None
        if m and lhs and lhs.result:
            ldims = lhs.result[0][1]
            for ci in _dims_of(m.group(1)):
                k *= ldims[ci]
        return 2.0 * out_elems * k
    if op.opcode == "convolution":
        rhs = comp.by_name.get(op.operands[1]) if len(op.operands) > 1 else None
        if rhs and rhs.result:
            kdims = rhs.result[0][1]
            m = re.search(r"dim_labels=[\w\d]+_([\w\d]+)->", op.line)
            ksz = 1
            if m:
                for i, ch in enumerate(m.group(1)):
                    if ch != "o":
                        ksz *= kdims[i]
            else:
                ksz = math.prod(kdims[:-1])
            return 2.0 * out_elems * ksz
    return 0.0


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    elem_ops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def as_dict(self):
        return {
            "flops": self.flops,
            "elem_ops": self.elem_ops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "collective_total": self.collective_total,
        }


def top_contributors(hlo: str, *, key: str = "bytes", n: int = 20):
    """Top-n (op line, metadata, contribution) — the profiling view used
    by the §Perf hillclimb (what to optimize next)."""
    comps, entries = parse_computations(hlo)
    mult_c, mult_b = _multipliers(comps, entries)
    items = []
    for cname, comp in comps.items():
        mc, mb = mult_c.get(cname, 0.0), mult_b.get(cname, 0.0)
        for op in comp.ops:
            if key == "flops":
                v = mc * _dot_flops(op, comp) if op.opcode in ("dot", "convolution") else 0.0
            elif key == "collective":
                v = mc * _nbytes(op.result) if any(
                    op.opcode in (c, c + "-start") for c in COLLECTIVES) else 0.0
            else:
                if op.opcode in _SKIP_OPS or any(op.opcode in (c, c + "-start") for c in COLLECTIVES):
                    v = 0.0
                else:
                    v = mb * _op_traffic(op, comp, comps)
            if v > 0:
                meta = re.search(r'op_name="([^"]*)"', op.line)
                items.append((v, op.opcode, meta.group(1) if meta else op.name,
                              op.line[:140]))
    items.sort(reverse=True)
    return items[:n]


_ELEMENTWISE_PASS = {"convert", "bitcast", "copy"}


def _param_effective_read(fused: Computation, idx: int) -> Optional[float]:
    """Bytes actually read from parameter `idx` of a fused computation.

    Chases element-wise pass-through chains (convert/bitcast/copy).  A
    parameter whose every use terminates in dynamic-slice reads only the
    slices; one that terminates as the in-place buffer (operand 0) of a
    dynamic-update-slice reads nothing extra (the write is counted at
    the root); anything else reads the full operand (None)."""
    pname = None
    for o in fused.ops:
        if o.opcode == "parameter" and re.search(rf"parameter\({idx}\)", o.line):
            pname = o.name
            break
    if pname is None:
        return None
    total = 0.0
    frontier = [pname]
    seen = set()
    while frontier:
        cur = frontier.pop()
        if cur in seen:
            continue
        seen.add(cur)
        for o in fused.ops:
            if cur not in o.operands:
                continue
            if o.opcode in _ELEMENTWISE_PASS:
                frontier.append(o.name)
            elif o.opcode in ("dynamic-slice", "slice", "gather"):
                total += _nbytes(o.result)
            elif o.opcode == "dynamic-update-slice" and o.operands[0] == cur:
                pass  # in-place target: write counted at the root
            else:
                return None  # fully read by some consumer
    return total


def _root_effective_write(fused: Computation) -> Optional[float]:
    """If the fusion root is (an element-wise wrap of) a dynamic-update-
    slice, the write traffic is the update window, not the buffer."""
    root = next((o for o in fused.ops if o.line.startswith("ROOT")), None)
    hops = 0
    while root is not None and root.opcode in _ELEMENTWISE_PASS and hops < 4:
        root = fused.by_name.get(root.operands[0]) if root.operands else None
        hops += 1
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = fused.by_name.get(root.operands[1]) if len(root.operands) > 1 else None
        if upd is not None:
            return 2.0 * _nbytes(upd.result)  # read update + write window
    return None


def _op_traffic(op: OpLine, comp: Computation, comps=None) -> float:
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * _nbytes(op.result)
    if op.opcode in ("dynamic-update-slice", "scatter"):
        upd = comp.by_name.get(op.operands[1]) if len(op.operands) > 1 else None
        return 2.0 * (_nbytes(upd.result) if upd else _nbytes(op.result))
    fused = None
    if op.opcode == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w\.\-]+)", op.line)
        if m:
            fused = comps.get(m.group(1))
    nb = _nbytes(op.result)
    if fused is not None:
        w = _root_effective_write(fused)
        if w is not None:
            nb = w
    for i, o in enumerate(op.operands):
        srcop = comp.by_name.get(o)
        if srcop is None:
            continue
        full = _nbytes(srcop.result)
        if fused is not None and full > 0:
            eff = _param_effective_read(fused, i)
            if eff is not None:
                full = min(full, eff)
        nb += full
    return nb


def _multipliers(comps, entries):
    mult_c: Dict[str, float] = defaultdict(float)
    mult_b: Dict[str, float] = defaultdict(float)
    if entries:
        entry = entries[0]
    else:
        called = {n for c in comps.values() for op in c.ops for _, n in _called_comps(op.line)}
        entry = next((c for c in comps if c not in called), next(iter(comps)))
    mult_c[entry] = 1.0
    mult_b[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            pairs = _called_comps(op.line)
            if not pairs:
                continue
            trip = _while_trip(op.line, comps, pairs) if op.opcode == "while" else 1.0
            for attr, n in pairs:
                if attr == "body":
                    mc, mb = mult_c[cname] * trip, mult_b[cname] * trip
                elif attr == "condition":
                    mc, mb = mult_c[cname], 0.0
                elif attr in ("calls", "to_apply"):
                    # fusion-internal computations run in registers/VMEM
                    # (traffic counted at the fusion boundary), but a
                    # plain `call` (XLA:CPU wraps loop bodies in
                    # parallel_* calls) IS the program — its callee
                    # keeps the caller's traffic multiplier
                    mb_in = mult_b[cname] if op.opcode == "call" else 0.0
                    mc, mb = mult_c[cname], mb_in
                else:
                    mc, mb = mult_c[cname], mult_b[cname]
                mult_c[n] += mc
                mult_b[n] += mb
                if n not in seen:
                    seen.add(n)
                    order.append(n)
    return mult_c, mult_b


def analyze(hlo: str) -> Analysis:
    comps, entries = parse_computations(hlo)
    # Two multipliers per computation: compute (flops/element ops) and
    # traffic (HBM bytes).  Fusion-internal computations keep compute
    # multipliers but contribute ZERO HBM traffic (registers/VMEM).
    mult_c, mult_b = _multipliers(comps, entries)

    out = Analysis()
    for cname, comp in comps.items():
        mc = mult_c.get(cname, 0.0)
        mb = mult_b.get(cname, 0.0)
        if mc == 0.0 and mb == 0.0:
            continue
        for op in comp.ops:
            matched_coll = None
            for c in COLLECTIVES:
                if op.opcode in (c, c + "-start"):
                    matched_coll = c
                    break
            if matched_coll:
                nb = _nbytes(op.result)
                # XLA:CPU promotes bf16 reductions to f32 (`..._promoted`
                # reducers with a convert-fed operand); TPU reduces in the
                # source dtype — count the unpromoted width.
                if "promoted" in op.line:
                    src = comp.by_name.get(op.operands[0]) if op.operands else None
                    if src is not None and ("convert" in src.opcode or "convert" in src.name):
                        nb /= 2.0
                out.collective_bytes[matched_coll] += mc * nb
                out.collective_counts[matched_coll] += mc
                out.hbm_bytes += mc * nb
                continue
            if op.opcode in ("dot", "convolution"):
                out.flops += mc * _dot_flops(op, comp)
            if op.opcode in _SKIP_OPS:
                continue
            if op.opcode not in ("dot", "convolution", "fusion"):
                out.elem_ops += mc * _nelems(op.result)
            # HBM traffic at the fusion boundary; sliced accesses (incl.
            # dynamic-slice/-update-slice fused into consumers) move only
            # the slice, not the full operand — see _op_traffic.
            out.hbm_bytes += mb * _op_traffic(op, comp, comps)
    return out
