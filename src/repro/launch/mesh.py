"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_dims(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
