"""Serving driver: batched generation under any numerics mode/policy.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --numerics plam_sim --batch 4 --prompt-len 16 --new-tokens 8

``--numerics-policy`` takes a per-site policy string (e.g.
``"default=plam_sim:16:1, attn=posit_quant:16:1, lm_head=f32"``) or the
path to a policy artifact saved by ``repro.numerics.calibrate``; the
single-mode ``--numerics`` flag is kept as sugar for
``default=<mode>``.  ``--prequantized`` encodes policy-selected weights
to posit patterns once at engine build (int16 storage, PLAM sites serve
through ``kernels.ops.plam_dense``).

``--continuous`` swaps the static batcher for the paged-KV
continuous-batching engine (dense/moe families), staggering request
arrivals to exercise per-step admission.  ``--tp N`` shards the
continuous engine tensor-parallel over a (data=1, model=N) mesh;
``--prefill-chunk M`` turns on chunked prefill (M must be a multiple of
the engine block size).  On CPU, ``--force-host-devices 8`` fakes an
8-device platform (sets XLA_FLAGS before jax initializes), which is how
CI exercises the sharded engine:

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --continuous --tp 2 --prefill-chunk 16 --force-host-devices 8
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--numerics", default="plam_sim",
                    choices=["f32", "bf16", "posit_quant", "plam_sim", "mitchell_f32"],
                    help="uniform mode; sugar for --numerics-policy 'default=<mode>'")
    ap.add_argument("--numerics-policy", default=None,
                    help="per-site policy string or path to a saved policy "
                         "artifact (overrides --numerics)")
    ap.add_argument("--prequantized", action="store_true",
                    help="encode policy-selected weights to posit patterns "
                         "once at engine build (serving-time weight storage)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="paged-KV continuous batching (dense/moe)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel ways for the continuous engine")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill width (0 = whole-prompt; "
                         "must be a multiple of the block size, 8)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft k tokens per slot per "
                         "step and verify k+1 positions in one batched call "
                         "(0 = off; requires greedy sampling)")
    ap.add_argument("--spec-draft", default="ngram",
                    help="drafter: 'ngram'/'ngram:N' (self-speculative "
                         "context lookup) or 'model:<arch>' (registry draft "
                         "model sharing the tokenizer)")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "recompute"],
                    help="preemptive scheduling under KV pressure: "
                         "'recompute' admits with prompt-sized allocations, "
                         "grows on demand, evicts the lowest-priority / "
                         "latest-arrival victim under pressure and resumes "
                         "it by recomputing its committed tokens")
    ap.add_argument("--priority", type=int, default=0,
                    help="priority for the demo requests (larger = more "
                         "deserving under --preemption recompute)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="wall-clock deadline per request, seconds from "
                         "submit; expired requests are cancelled with "
                         "whatever output they committed")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="force N host (CPU) devices via XLA_FLAGS — must be "
                         "set before jax initializes, so it only works as a "
                         "flag, never from inside python")
    args = ap.parse_args()

    if args.force_host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.force_host_devices}"
        )

    # deferred until after XLA_FLAGS is settled: importing repro pulls in jax
    import dataclasses

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, get_config
    from repro.core.policy import describe, load_policy_arg, parse_policy
    from repro.serving.engine import (
        ContinuousBatchingEngine,
        Engine,
        PagedServeConfig,
        ServeConfig,
    )

    if args.arch not in ARCHS:
        raise SystemExit(f"unknown arch {args.arch!r}; pick from {sorted(ARCHS)}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, param_dtype="float32", act_dtype="float32")
    if args.numerics_policy is not None:
        policy = load_policy_arg(args.numerics_policy)
    else:  # single-mode sugar: default=<mode>
        policy = parse_policy(f"default={args.numerics}")
    cfg = cfg.with_numerics(policy)
    numerics_label = describe(cfg.numerics)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ for multimodal serving demos")

    rng = np.random.default_rng(args.seed)
    if args.continuous:
        max_seq = args.prompt_len + args.new_tokens
        eng = ContinuousBatchingEngine(
            cfg, key=jax.random.PRNGKey(args.seed),
            pcfg=PagedServeConfig(
                block_size=8, num_blocks=4 * args.batch * (max_seq // 8 + 2),
                max_slots=args.batch, max_seq_len=max_seq + 8,
                temperature=args.temperature, seed=args.seed,
                tp=args.tp, prefill_chunk=args.prefill_chunk,
                prequantize=args.prequantized,
                spec_k=args.spec_k, spec_draft=args.spec_draft,
                preemption=args.preemption))
        reqs = [eng.submit(
            rng.integers(0, cfg.vocab, args.prompt_len).tolist(),
            max_new_tokens=args.new_tokens, arrival_step=i,
            priority=args.priority, deadline_s=args.deadline_s)
            for i in range(args.batch)]
        done = eng.run()
        spec = (f" spec_k={args.spec_k} "
                f"accept={eng.stats.acceptance_rate():.1%} "
                f"tok/verify={eng.stats.tokens_per_verify_step():.2f}"
                if args.spec_k else "")
        if args.preemption != "off" or args.deadline_s is not None:
            spec += (f" preemptions={eng.stats.preemptions}"
                     f" resumes={eng.stats.resumes}"
                     f" deadline_cancelled={eng.stats.deadline_cancelled}")
        print(f"arch={cfg.name} numerics={numerics_label!r} engine=continuous "
              f"tp={args.tp} prefill_chunk={args.prefill_chunk} "
              f"steps={eng.stats.steps} pad_waste={eng.stats.padding_waste():.1%} "
              f"step_p50={eng.stats.latency_p50() * 1e3:.1f}ms "
              f"step_p95={eng.stats.latency_p95() * 1e3:.1f}ms" + spec)
        for i, r in enumerate(reqs):
            print(f"req[{i}]: {done[r.rid]}")
        return

    if (args.tp > 1 or args.prefill_chunk or args.spec_k
            or args.preemption != "off" or args.deadline_s is not None
            or args.priority):
        raise SystemExit("--tp / --prefill-chunk / --spec-k / --preemption / "
                         "--deadline-s / --priority require --continuous")
    eng = Engine(cfg, key=jax.random.PRNGKey(args.seed), prequantize=args.prequantized)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32))}
    out = eng.generate(prompts, ServeConfig(
        max_new_tokens=args.new_tokens, temperature=args.temperature, seed=args.seed))
    print(f"arch={cfg.name} numerics={numerics_label!r} "
          f"step_p50={eng.stats.latency_p50() * 1e3:.1f}ms "
          f"step_p95={eng.stats.latency_p95() * 1e3:.1f}ms")
    for i, row in enumerate(np.asarray(out)):
        print(f"batch[{i}]: {row.tolist()}")


if __name__ == "__main__":
    main()
