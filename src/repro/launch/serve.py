"""Serving driver: batched generation under any numerics mode/policy.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --numerics-policy "default=plam_sim:16:1" --batch 4 \
      --prompt-len 16 --new-tokens 8

``--numerics-policy`` takes a per-site policy string (e.g.
``"default=plam_sim:16:1, attn=posit_quant:16:1, lm_head=f32"``) or the
path to a policy artifact saved by ``repro.numerics.calibrate``.
``--prequantized`` encodes policy-selected weights to posit patterns
once at engine build (int16 storage, PLAM sites serve through
``kernels.ops.plam_dense``).

``--continuous`` swaps the static batcher for the paged-KV
continuous-batching engine (dense/moe families), staggering request
arrivals to exercise per-step admission.  ``--tp N`` shards the
continuous engine tensor-parallel over a (data=1, model=N) mesh;
``--prefill-chunk M`` turns on chunked prefill (M must be a multiple of
the engine block size).  On CPU, ``--force-host-devices 8`` fakes an
8-device platform (sets XLA_FLAGS before jax initializes), which is how
CI exercises the sharded engine.

Engine options beyond those first-class flags are spelled ``--opt
KEY=VAL`` (repeatable), with KEY any ``repro.serving.ServeOptions``
field — e.g. ``--opt spec_k=4 --opt preemption=recompute`` or ``--opt
prefix_cache=true`` (content-addressed KV reuse across requests that
share a prompt prefix; the summary line then reports the block
hit/miss counts and prompt tokens skipped).  The old
split spellings (``--numerics``, ``--spec-k``, ``--spec-draft``,
``--preemption``, ``--priority``, ``--deadline-s``) still work but are
deprecated: using any of them emits ONE consolidated
DeprecationWarning naming the flags and their ``--opt`` replacements,
and routes through the exact same ``ServeOptions`` — behavior
identical, spelling legacy.

Observability (see docs/observability.md): tracing is on by default;
``--trace-out PATH`` writes the engine trace after the run (Chrome
trace_event JSON when PATH ends in ``.json`` — load it in Perfetto —
JSON-lines otherwise), ``--metrics-out PATH`` writes a Prometheus text
snapshot, and ``--profile`` wraps each engine phase in a
``jax.profiler`` TraceAnnotation for profiler captures.
"""
import argparse
import os
import warnings

# legacy flag -> (ServeOptions field it maps to, dest on the parsed args)
_LEGACY_FLAGS = {
    "--spec-k": ("spec_k", "spec_k"),
    "--spec-draft": ("spec_draft", "spec_draft"),
    "--preemption": ("preemption", "preemption"),
    "--priority": ("priority", "priority"),
    "--deadline-s": ("deadline_s", "deadline_s"),
}


def make_parser() -> argparse.ArgumentParser:
    """The CLI surface, importable without touching jax (tests parse
    flag spellings against it; main() keeps XLA_FLAGS ordering)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--numerics", default=None,
                    choices=["f32", "bf16", "posit_quant", "plam_sim",
                             "mitchell_f32"],
                    help="DEPRECATED sugar for --numerics-policy "
                         "'default=<mode>'")
    ap.add_argument("--numerics-policy", default=None,
                    help="per-site policy string or path to a saved policy "
                         "artifact (default: 'default=plam_sim')")
    ap.add_argument("--prequantized", action="store_true",
                    help="encode policy-selected weights to posit patterns "
                         "once at engine build (serving-time weight storage)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="paged-KV continuous batching (dense/moe)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel ways for the continuous engine")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill width (0 = whole-prompt; "
                         "must be a multiple of the block size, 8)")
    ap.add_argument("--opt", action="append", default=[], metavar="KEY=VAL",
                    help="set any repro.serving.ServeOptions field, e.g. "
                         "--opt spec_k=4 --opt preemption=recompute "
                         "(repeatable; applied after first-class flags)")
    # -- deprecated split spellings (use --opt) ---------------------------
    ap.add_argument("--spec-k", type=int, default=None,
                    help="DEPRECATED: use --opt spec_k=K")
    ap.add_argument("--spec-draft", default=None,
                    help="DEPRECATED: use --opt spec_draft=SPEC")
    ap.add_argument("--preemption", default=None,
                    choices=["off", "recompute"],
                    help="DEPRECATED: use --opt preemption=MODE")
    ap.add_argument("--priority", type=int, default=None,
                    help="DEPRECATED: use --opt priority=P")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="DEPRECATED: use --opt deadline_s=S")
    # -- observability ----------------------------------------------------
    ap.add_argument("--trace-out", default=None,
                    help="write the engine trace here after the run: Chrome "
                         "trace_event JSON when the path ends in .json "
                         "(open in Perfetto), JSON-lines otherwise")
    ap.add_argument("--metrics-out", default=None,
                    help="write a Prometheus text-format metrics snapshot "
                         "here after the run")
    ap.add_argument("--profile", action="store_true",
                    help="annotate engine phases with jax.profiler "
                         "TraceAnnotations (visible inside a profiler "
                         "capture)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="force N host (CPU) devices via XLA_FLAGS — must be "
                         "set before jax initializes, so it only works as a "
                         "flag, never from inside python")
    return ap


def _coerce(field, raw: str):
    """Parse an --opt VAL string against a ServeOptions dataclass field."""
    low = raw.lower()
    if low in ("true", "false"):
        return low == "true"
    if low in ("none", "null"):
        return None
    for conv in (int, float):
        try:
            return conv(raw)
        except ValueError:
            continue
    return raw


def options_from_args(args):
    """Build the run's ServeOptions from parsed args.

    The deprecated split flags are folded in first (emitting ONE
    consolidated DeprecationWarning naming every legacy flag used),
    then ``--opt KEY=VAL`` overrides are applied on top — so the legacy
    spelling and its --opt replacement produce identical options.
    """
    import dataclasses

    from repro.serving import ServeOptions

    legacy_used = []
    legacy_vals = {}
    for flag, (field, dest) in _LEGACY_FLAGS.items():
        val = getattr(args, dest)
        if val is not None:
            legacy_used.append(f"{flag} -> --opt {field}=...")
            legacy_vals[field] = val
    if args.numerics is not None:
        legacy_used.append(
            "--numerics -> --numerics-policy 'default=<mode>'"
        )
    if legacy_used:
        warnings.warn(
            "deprecated serve flags: " + "; ".join(sorted(legacy_used))
            + ". These spellings keep working (identical behavior via "
            "repro.serving.ServeOptions) but will be removed; switch to the "
            "replacements shown.",
            DeprecationWarning,
            stacklevel=2,
        )

    max_seq = args.prompt_len + args.new_tokens
    opts = ServeOptions(
        max_new_tokens=args.new_tokens,
        temperature=args.temperature,
        seed=args.seed,
        engine="continuous" if args.continuous else "static",
        block_size=8,
        num_blocks=4 * args.batch * (max_seq // 8 + 2),
        max_slots=args.batch,
        max_seq_len=max_seq + 8,
        tp=args.tp,
        prefill_chunk=args.prefill_chunk,
        prequantize=args.prequantized,
        profile=args.profile,
        **legacy_vals,
    )
    fields = {f.name: f for f in dataclasses.fields(ServeOptions)}
    overrides = {}
    for kv in args.opt:
        key, sep, raw = kv.partition("=")
        if not sep or key not in fields:
            raise SystemExit(
                f"bad --opt {kv!r}: expected KEY=VAL with KEY a ServeOptions "
                f"field ({', '.join(sorted(fields))})"
            )
        overrides[key] = _coerce(fields[key], raw)
    return dataclasses.replace(opts, **overrides)


def main():
    args = make_parser().parse_args()

    if args.force_host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.force_host_devices}"
        )

    # deferred until after XLA_FLAGS is settled: importing repro pulls in jax
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, get_config
    from repro.core.policy import describe, load_policy_arg, parse_policy
    from repro.serving import ContinuousBatchingEngine, build_engine

    opts = options_from_args(args)

    if args.arch not in ARCHS:
        raise SystemExit(f"unknown arch {args.arch!r}; pick from {sorted(ARCHS)}")

    cfg = get_config(args.arch)
    if args.reduced:
        import dataclasses

        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, param_dtype="float32", act_dtype="float32")
    if args.numerics_policy is not None:
        policy = load_policy_arg(args.numerics_policy)
    else:  # single-mode default (or deprecated --numerics sugar)
        policy = parse_policy(f"default={args.numerics or 'plam_sim'}")
    cfg = cfg.with_numerics(policy)
    numerics_label = describe(cfg.numerics)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("use examples/ for multimodal serving demos")

    rng = np.random.default_rng(args.seed)
    if opts.engine == "continuous":
        eng = build_engine(cfg, opts, key=jax.random.PRNGKey(args.seed))
        handles = [eng.submit(
            rng.integers(0, cfg.vocab, args.prompt_len).tolist(),
            arrival_step=i, **opts.submit_kwargs())
            for i in range(args.batch)]
        done = eng.run()
        spec = (f" spec_k={opts.spec_k} "
                f"accept={eng.stats.acceptance_rate():.1%} "
                f"tok/verify={eng.stats.tokens_per_verify_step():.2f}"
                if opts.spec_k else "")
        if opts.preemption != "off" or opts.deadline_s is not None:
            spec += (f" preemptions={eng.stats.preemptions}"
                     f" resumes={eng.stats.resumes}"
                     f" deadline_cancelled={eng.stats.deadline_cancelled}")
        if opts.prefix_cache:
            al = eng.allocator
            spec += (f" prefix_hits={al.hits} prefix_misses={al.misses}"
                     f" prefill_tokens_saved={al.tokens_saved}"
                     f" prefix_evictions={al.evictions}")
        print(f"arch={cfg.name} numerics={numerics_label!r} engine=continuous "
              f"tp={opts.tp} prefill_chunk={opts.prefill_chunk} "
              f"steps={eng.stats.steps} pad_waste={eng.stats.padding_waste():.1%} "
              f"step_p50={eng.stats.latency_p50() * 1e3:.1f}ms "
              f"step_p95={eng.stats.latency_p95() * 1e3:.1f}ms" + spec)
        for i, h in enumerate(handles):
            print(f"req[{i}]: {done[h.rid]}")
            bd = h.breakdown()
            if bd is not None:
                print(f"  queue={bd.queue_s * 1e3:.1f}ms "
                      f"prefill={bd.prefill_s * 1e3:.1f}ms "
                      f"decode={bd.decode_s * 1e3:.1f}ms "
                      f"parked={bd.parked_s * 1e3:.1f}ms "
                      f"ttft={bd.first_token_s * 1e3:.1f}ms")
        _write_artifacts(args, eng)
        return

    if (opts.tp > 1 or opts.prefill_chunk or opts.spec_k
            or opts.preemption != "off" or opts.deadline_s is not None
            or opts.priority):
        raise SystemExit("tp / prefill_chunk / spec_k / preemption / "
                         "deadline_s / priority require --continuous")
    eng = build_engine(cfg, opts, key=jax.random.PRNGKey(args.seed))
    assert not isinstance(eng, ContinuousBatchingEngine)
    prompts = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32))}
    out = eng.generate(prompts, opts.static())
    print(f"arch={cfg.name} numerics={numerics_label!r} "
          f"step_p50={eng.stats.latency_p50() * 1e3:.1f}ms "
          f"step_p95={eng.stats.latency_p95() * 1e3:.1f}ms")
    for i, row in enumerate(np.asarray(out)):
        print(f"batch[{i}]: {row.tolist()}")
    _write_artifacts(args, eng)


def _write_artifacts(args, eng) -> None:
    """Honor --trace-out / --metrics-out after a run."""
    trace = getattr(eng, "trace", None)
    if args.trace_out:
        if trace is None:
            print(f"trace-out skipped: engine has no trace "
                  f"(static engine or trace=False): {args.trace_out}")
        elif args.trace_out.endswith(".json"):
            trace.to_chrome_trace(args.trace_out)
            print(f"wrote Chrome trace: {args.trace_out}")
        else:
            trace.to_jsonl(args.trace_out)
            print(f"wrote trace events: {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(eng.metrics.to_prometheus_text())
        print(f"wrote metrics: {args.metrics_out}")


if __name__ == "__main__":
    main()
