"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the trip-count-corrected HLO
analysis recorded by dryrun.py:

  compute term    = HLO_MXU_FLOPs/chip / peak_MXU  +  elem_ops/chip / peak_VPU
  memory term     = HLO_bytes/chip / HBM_bw
  collective term = link_bytes/chip / link_bw   (per-type ring factors)

Hardware constants (TPU v5e-class, per task spec): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.  VPU peak is a documented heuristic
(8-wide VPU issue vs MXU): 197/16 ~= 12.3 T elementwise ops/s.

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*D (prefill) /
2*N_active*B (decode) convention plus attention quadratic terms, with
N_active counting MoE experts at top_k/E utilization.

The reported `roofline_fraction` is an MFU-style bound:
  (model_flops_per_chip / peak_MXU) / max(compute, memory, collective)
i.e. what fraction of the best-achievable step time is useful model
math.  This is the §Perf score; hillclimbing drives the dominant term
down and the fraction up.
"""
from __future__ import annotations

import glob
import json
import math
import os
from typing import Dict

PEAK_MXU = 197e12  # bf16 FLOP/s per chip
PEAK_VPU = PEAK_MXU / 16  # heuristic elementwise op/s per chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

# ring-algorithm byte multipliers on result bytes
_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def count_params(cfg) -> Dict[str, float]:
    """Total / active (MoE top-k utilized) / encoder / decoder params."""
    import jax
    from repro.models import build

    api = build(cfg)
    tree = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    total = routed = enc = 0
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        sz = math.prod(leaf.shape)
        total += sz
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if "moe/" in pstr and pstr.split("/")[-1] in ("wg", "wu", "wd"):
            routed += sz
        if "enc_layers" in pstr or "frontend" in pstr:
            enc += sz
    active = total - routed
    if cfg.n_experts:
        active += routed * cfg.top_k / cfg.n_experts
    return {"total": float(total), "active": float(active),
            "enc": float(enc), "dec": float(total - enc)}


def model_flops(cfg, shape, params: Dict[str, float]) -> float:
    """Ideal useful FLOPs for the step (global, all chips)."""
    n_act = params["active"]
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        pass  # patch prefix replaces tokens 1:1; same token count
    d_attn = (cfg.n_heads or 0) * cfg.hd if cfg.n_heads else 0

    def dense_flops(mult):
        if cfg.family == "encdec":
            # encoder sees s source frames, decoder sees <=4096 targets
            tgt = min(s, 4096)
            return mult * (params["enc"] * b * s + params["dec"] * b * tgt)
        return mult * n_act * b * s

    if shape.kind == "train":
        flops = dense_flops(6.0)
        # causal attention quadratic term: fwd 2*2*(S^2/2)*d_attn per layer
        if d_attn and cfg.family != "encdec":
            flops += 3 * 2 * 2 * 0.5 * cfg.n_layers * s * s * d_attn * b
        return flops
    if shape.kind == "prefill":
        flops = dense_flops(2.0)
        if d_attn and cfg.family != "encdec":
            flops += 2 * 2 * 0.5 * cfg.n_layers * s * s * d_attn * b
        return flops
    # decode: one token over a cache of length s
    flops = 2.0 * n_act * b
    if d_attn and cfg.family not in ("ssm",):
        layers = cfg.n_layers if cfg.family != "hybrid" else cfg.n_layers // max(cfg.shared_attn_every, 1)
        kv_d = (cfg.n_kv or 0) * cfg.hd
        flops += 2 * 2 * layers * s * (kv_d or d_attn) * b
    return flops


def model_bytes(cfg, shape, params) -> float:
    """Ideal HBM traffic for the step (global): weights read once +
    KV/state cache read+written once (decode) or activations (train)."""
    wb = params["active"] * 2  # bf16 weights
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.family == "ssm":
            cache = cfg.n_layers * b * (cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim) \
                * cfg.ssm_state * cfg.ssm_head_dim * 4
        elif cfg.family == "hybrid":
            n_inv = cfg.n_layers // max(cfg.shared_attn_every, 1)
            cache = n_inv * b * s * cfg.n_kv * (2 * cfg.d_model // cfg.n_heads) * 2 * 2
            cache += cfg.n_layers * b * 2 * cfg.d_model * cfg.ssm_state * 4
        else:
            layers = cfg.dec_layers if cfg.family == "encdec" else cfg.n_layers
            slen = min(s, 4096) if cfg.family == "encdec" else s
            cache = layers * b * slen * cfg.n_kv * cfg.hd * 2 * 2
        return wb + cache
    # train/prefill: weights + one activations pass (rough ideal)
    act = cfg.n_layers * b * min(s, 524_288) * cfg.d_model * 2
    return wb + act


def roofline_row(rec: dict, cfg, shape) -> dict:
    ndev = rec["devices"]
    t_compute = rec["flops"] / PEAK_MXU + rec.get("elem_ops", 0) / PEAK_VPU
    t_memory = rec["bytes_accessed"] / HBM_BW
    coll = rec["collectives"]["collective_bytes"]
    t_coll = sum(_COLL_FACTOR.get(k, 1.0) * v for k, v in coll.items()) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    params = count_params(cfg)
    mf = model_flops(cfg, shape, params)
    mf_dev = mf / ndev
    mb_dev = model_bytes(cfg, shape, params) / ndev
    # ideal step time: whichever resource the *ideal* program needs more of
    t_ideal = max(mf_dev / PEAK_MXU, mb_dev / HBM_BW)
    t_bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "numerics": rec.get("numerics", "?"),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_per_dev": rec["flops"],
        "useful_ratio": mf_dev / rec["flops"] if rec["flops"] else float("nan"),
        "mem_useful_ratio": mb_dev / rec["bytes_accessed"] if rec["bytes_accessed"] else float("nan"),
        "roofline_fraction": t_ideal / t_bound if t_bound else float("nan"),
        "params_total": params["total"],
        "params_active": params["active"],
        "tag": rec.get("tag", ""),
    }


def load_and_report(dryrun_dir="experiments/dryrun", out_md="experiments/roofline.md",
                    mesh_filter="16x16"):
    from repro.configs import get_config, shape_by_name

    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if "skipped" in rec or rec.get("mesh") != mesh_filter or rec.get("tag"):
            continue
        cfg = get_config(rec["arch"])
        shape = shape_by_name(rec["shape"])
        rows.append(roofline_row(rec, cfg, shape))

    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | dominant | compute s | memory s | collective s | "
           "useful-flops | useful-bytes | roofline frac |")
    lines = [hdr, "|" + "---|" * 9]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"{r['useful_ratio']:.2f} | {r['mem_useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    md = "\n".join(lines)
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write(md + "\n")
    return rows, md


def reanalyze(dryrun_dir="experiments/dryrun", hlo_dir="experiments/hlo"):
    """Re-run the HLO analyzer over archived compiled modules (no
    recompiles) and refresh the dry-run JSON records in place."""
    import gzip

    from repro.launch.hlo_analysis import analyze

    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if "skipped" in rec:
            continue
        stem = os.path.splitext(os.path.basename(f))[0]
        hlo_path = os.path.join(hlo_dir, stem + ".txt.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as fh:
            ana = analyze(fh.read())
        rec["flops"] = ana.flops
        rec["elem_ops"] = ana.elem_ops
        rec["bytes_accessed"] = ana.hbm_bytes
        rec["collectives"] = ana.as_dict()
        json.dump(rec, open(f, "w"), indent=2)
        print(f"reanalyzed {stem}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--reanalyze", action="store_true")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.dir)
    rows, md = load_and_report(args.dir, mesh_filter=args.mesh)
    print(md)
