"""Training driver: any assigned arch (reduced or full), any numerics.

CPU-scale example (reduced config, posit16, fault-tolerant):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 50 --numerics posit_quant --ckpt-dir /tmp/ck --simulate-failure 30

``--numerics-policy`` trains under a per-site policy (string or saved
artifact); the policy serializes into every checkpoint manifest so
serving restores the exact numerics.  The single-mode flags
(--numerics/--posit-n/--posit-es/--carrier) stay as sugar for a
uniform policy.

On a real cluster the same entry point runs the full config against the
production mesh (params/optimizer sharded per repro.parallel rules).
"""
import argparse
import dataclasses

import jax

from repro.configs import ARCHS, get_config
from repro.core.modes import NumericsConfig
from repro.core.policy import describe, load_policy_arg
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.optim.optimizers import OptConfig
from repro.train.checkpoint import policy_extra
from repro.train.loop import FailureInjector, TrainConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--numerics", default="posit_quant",
                    choices=["f32", "bf16", "posit_quant", "plam_sim", "mitchell_f32"],
                    help="uniform mode; sugar for --numerics-policy 'default=<mode>'")
    ap.add_argument("--numerics-policy", default=None,
                    help="per-site policy string or saved-artifact path "
                         "(overrides the single-mode flags)")
    ap.add_argument("--posit-n", type=int, default=16)
    ap.add_argument("--posit-es", type=int, default=1)
    ap.add_argument("--carrier", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adam", "sgd", "nesterov"])
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, param_dtype="float32", act_dtype="float32")
    if args.numerics_policy is not None:
        cfg = cfg.with_numerics(load_policy_arg(args.numerics_policy))
    else:
        cfg = cfg.with_numerics(NumericsConfig(
            mode=args.numerics, n=args.posit_n, es=args.posit_es,
            carrier=args.carrier))
    api = build(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))))
    print(f"arch={cfg.name}{' (reduced)' if args.reduced else ''} "
          f"params={n_params/1e6:.1f}M numerics={describe(cfg.numerics)!r}")

    if cfg.family == "encdec" or cfg.family == "vlm":
        raise SystemExit("use examples/ for multimodal training demos; LM families here")

    dcfg = DataConfig(seed=0, vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch)
    tcfg = TrainConfig(
        opt=OptConfig(name=args.opt, lr=args.lr),
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        ckpt_extra=policy_extra(cfg.numerics),
    )
    failure = FailureInjector([args.simulate_failure]) if args.simulate_failure else None
    _, _, info = run(
        loss_fn=api.train_loss,
        init_params_fn=lambda: api.init(jax.random.PRNGKey(0)),
        batch_fn=lambda s: lm_batch(dcfg, s),
        tcfg=tcfg,
        num_steps=args.steps,
        failure=failure,
    )
    for s, l in info["history"]:
        print(f"step {s:5d}  loss {l:.4f}")
    print(f"restarts={info['restarts']} final_step={info['final_step']}")


if __name__ == "__main__":
    main()
