"""Optimizers (SGD / Nesterov / AdamW) as pure pytree transforms.

The paper's experiments use SGD, Nesterov and Adam (Table I); AdamW is
the default for the LM-scale runs.  Optimizer state sharding follows
ZeRO-1: each state tensor inherits its parameter's TP sharding and is
*additionally* sharded over the data axis on the first divisible
replicated dimension (for scanned layers that is the [L] axis — an
FSDP-over-layers layout), so per-device optimizer memory scales with
1/(dp*tp).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import pspec, sanitize, spec_for_param, _path_str


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adam | sgd | nesterov
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0


def init_state(cfg: OptConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.name in ("adam", "adamw"):
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name in ("sgd", "nesterov"):
        return {"mu": jax.tree.map(zeros, params), "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state).  Gradients are clipped by global
    norm; master math in f32, params cast back to their storage dtype."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state["step"] + 1
    lr = cfg.lr * lr_scale

    if cfg.name in ("adam", "adamw"):
        b1, b2 = cfg.beta1, cfg.beta2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if cfg.name == "adamw" and cfg.weight_decay:
                u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    # SGD / Nesterov momentum
    mu = jax.tree.map(lambda mu, g: cfg.momentum * mu + g, state["mu"], grads)
    if cfg.name == "nesterov":
        upd_tree = jax.tree.map(lambda g, mu: g + cfg.momentum * mu, grads, mu)
    else:
        upd_tree = mu
    new_params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype), params, upd_tree
    )
    return new_params, {"mu": mu, "step": step}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------

def _zero1_dims(path: str, leaf, mesh: Mesh, rules=None):
    dims = list(sanitize(mesh, spec_for_param(path, leaf.ndim, rules), leaf.shape))
    if "data" in mesh.axis_names:
        dsz = mesh.shape["data"]
        for i, d in enumerate(dims):
            if d is None and leaf.shape[i] % dsz == 0 and leaf.shape[i] >= dsz:
                dims[i] = "seq"  # logical 'seq' resolves to the data axis
                break
    return tuple(dims)


def state_shardings(cfg: OptConfig, mesh: Mesh, params, rules=None):
    """NamedSharding pytree for init_state(params) under ZeRO-1."""

    def shard_like_params(tree):
        def one(path, leaf):
            dims = _zero1_dims(_path_str(path), leaf, mesh, rules)
            return NamedSharding(mesh, pspec(mesh, dims))
        return jax.tree_util.tree_map_with_path(one, tree)

    params_sh = shard_like_params(params)
    scalar = NamedSharding(mesh, P())
    if cfg.name in ("adam", "adamw"):
        return {"m": params_sh, "v": params_sh, "step": scalar}
    return {"mu": params_sh, "step": scalar}
