"""Serving engine: prefill+decode must agree with full-sequence forward."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.models import build
from repro.models.transformer import (
    default_positions,
    embed_tokens,
    lm_backbone,
    lm_logits,
)
from repro.serving.engine import Engine, ServeConfig

CFG = ModelConfig(
    name="toy-serve", family="dense", n_layers=3, d_model=64, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128, vocab=97,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def setup():
    api = build(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def test_incremental_decode_matches_full_forward(setup):
    """logits(prefill 8 tokens, then decode 4) == logits(forward over 12)."""
    api, params = setup
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 97, (2, 12)).astype(np.int32))

    # full forward
    x = embed_tokens(CFG, params, toks)
    pos = default_positions(CFG, 2, 12)
    hidden, _ = lm_backbone(CFG, params, x, pos)
    full_logits = np.asarray(lm_logits(CFG, params, hidden), np.float32)

    # prefill 8 + cache sized 12, then 4 decode steps
    from repro.models.transformer import kv_cache_init, prefill as tf_prefill, decode_step

    caches = kv_cache_init(CFG, 2, 12, jnp.float32)
    logits_p, caches = tf_prefill(CFG, params, toks[:, :8], caches)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32), full_logits[:, 7], rtol=2e-3, atol=2e-3)
    for i in range(8, 12):
        logits_d, caches = decode_step(CFG, params, toks[:, i:i + 1], caches, jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32), full_logits[:, i], rtol=2e-3, atol=2e-3,
            err_msg=f"step {i}")


def test_engine_greedy_generation(setup):
    api, params = setup
    eng = Engine(CFG, params)
    rng = np.random.default_rng(1)
    prompt = {"tokens": jnp.asarray(rng.integers(0, 97, (2, 8)).astype(np.int32))}
    out = eng.generate(prompt, ServeConfig(max_new_tokens=5))
    assert out.shape == (2, 5)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < 97))
    # deterministic
    out2 = eng.generate(prompt, ServeConfig(max_new_tokens=5))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_engine_latency_stats(setup):
    """The static engine emits the same per-step counters the continuous
    engine keeps (serve_bench reads them with no guards): step 0 =
    prefill, then one entry per lockstep decode.  Wall latencies need
    the opt-in time_steps sync; without it the counters still fill and
    the percentile helpers degrade to 0.0 instead of raising."""
    api, params = setup
    eng = Engine(CFG, params)
    rng = np.random.default_rng(4)
    prompt = {"tokens": jnp.asarray(rng.integers(0, 97, (2, 8)).astype(np.int32))}
    eng.generate(prompt, ServeConfig(max_new_tokens=5, time_steps=True))
    st = eng.stats
    assert st.steps == 5 and st.decode_steps == 4 and st.prefills == 1
    assert len(st.step_latency_s) == 5
    assert st.generated_tokens == 10  # batch 2 x 5 tokens
    assert st.prefill_tokens == 16
    assert st.latency_p95() >= st.latency_p50() > 0.0
    # stats reset per generate(); default = counters only, no sync
    eng.generate(prompt, ServeConfig(max_new_tokens=2))
    assert eng.stats.steps == 2
    assert eng.stats.step_latency_s == []
    assert eng.stats.latency_p95() == 0.0


def test_engine_encdec_family():
    """Enc-dec generate: the encoder output is recomputed once from the
    prompt frames and fed to every decode step (it is not part of the
    caches prefill returns), and the decoder KV cache is grown so decode
    writes land past the prompt instead of clamping onto its tail."""
    cfg = ModelConfig(
        name="toy-encdec", family="encdec", n_layers=4, enc_layers=2,
        dec_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16, d_ff=128,
        vocab=61, frontend="audio", frontend_dim=16,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32",
    )
    eng = Engine(cfg)
    rng = np.random.default_rng(6)
    prompt = {
        "frames": jnp.asarray(rng.standard_normal((2, 12, 16)).astype(np.float32)),
        "tokens": jnp.asarray(rng.integers(0, 61, (2, 6)).astype(np.int32)),
    }
    out = eng.generate(prompt, ServeConfig(max_new_tokens=4))
    assert out.shape == (2, 4)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < 61))
    # deterministic across calls (enc cache reset + recomputed per call)
    out2 = eng.generate(prompt, ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_engine_ssm_family():
    cfg = ModelConfig(
        name="toy-ssm", family="ssm", n_layers=2, d_model=64, vocab=61,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=8,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32", sub_quadratic=True,
    )
    eng = Engine(cfg)
    prompt = {"tokens": jnp.asarray(np.arange(16, dtype=np.int32)[None].repeat(2, 0))}
    out = eng.generate(prompt, ServeConfig(max_new_tokens=4))
    assert out.shape == (2, 4)


def test_ssm_decode_matches_prefill_extension():
    """SSM: prefill(t0..t8) then decode t8 == prefill(t0..t9) last logits."""
    cfg = ModelConfig(
        name="toy-ssm2", family="ssm", n_layers=2, d_model=64, vocab=61,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=4,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32", sub_quadratic=True,
    )
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, 61, (2, 9)).astype(np.int32))
    l_long, _ = jax.jit(api.prefill)(params, {"tokens": toks})

    l_short, caches = jax.jit(api.prefill)(params, {"tokens": toks[:, :8]})
    l_dec, _ = jax.jit(api.decode_step)(
        params, {"token": toks[:, 8:9], "caches": caches, "cache_len": jnp.int32(8)})
    np.testing.assert_allclose(
        np.asarray(l_dec[:, 0], np.float32), np.asarray(l_long[:, 0], np.float32),
        rtol=2e-3, atol=2e-3)
