"""Component-level model tests: MoE routing, SSD math, RoPE, chunked CE."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.modes import NumericsConfig
from repro.models.common import apply_rope, causal_mask
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import _ssd_chunked, mamba2_apply, mamba2_cache_init, mamba2_init

F32 = NumericsConfig(mode="f32")


# ---------------------------------------------------------------------------
# SSD: the chunked algorithm must equal the naive recurrence
# ---------------------------------------------------------------------------

def _ssd_naive(xh, bs, cs, dt, a_log):
    b, s, h, hd = xh.shape
    ds = bs.shape[-1]
    a = np.exp(-np.exp(np.asarray(a_log))[None, None, :] * np.asarray(dt))  # [B,S,H]
    state = np.zeros((b, h, ds, hd))
    ys = []
    for t in range(s):
        state = a[:, t][:, :, None, None] * state + np.einsum(
            "bs,bhd->bhsd", np.asarray(bs)[:, t], np.asarray(xh)[:, t] * np.asarray(dt)[:, t][..., None])
        ys.append(np.einsum("bs,bhsd->bhd", np.asarray(cs)[:, t], state))
    return np.stack(ys, axis=1), state


@pytest.mark.parametrize("s,chunk", [(16, 4), (16, 16), (12, 5), (32, 8)])
def test_ssd_chunked_equals_naive(s, chunk):
    rng = np.random.default_rng(0)
    b, h, hd, ds = 2, 3, 4, 5
    xh = jnp.asarray(rng.standard_normal((b, s, h, hd)).astype(np.float32))
    bs = jnp.asarray(rng.standard_normal((b, s, ds)).astype(np.float32))
    cs = jnp.asarray(rng.standard_normal((b, s, ds)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)).astype(np.float32))
    a_log = jnp.asarray(rng.uniform(-1, 1, (h,)).astype(np.float32))
    y, hfin = _ssd_chunked(xh, bs, cs, dt, a_log, chunk)
    y_ref, h_ref = _ssd_naive(xh, bs, cs, dt, a_log)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hfin), h_ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_equals_prefill_stepwise():
    """Running T single-token decode steps == one chunked prefill."""
    rng = np.random.default_rng(1)
    d, s = 32, 8
    kw = dict(expand=2, head_dim=16, d_state=8, chunk=4)
    p = mamba2_init(jax.random.PRNGKey(0), d, d_conv=4, **{k: v for k, v in kw.items() if k != "chunk"})
    x = jnp.asarray(rng.standard_normal((2, s, d)).astype(np.float32))
    y_all, _ = mamba2_apply(p, x, F32, **kw)
    cache = mamba2_cache_init(2, d, d_conv=4, **{k: v for k, v in kw.items() if k != "chunk"})
    outs = []
    for t in range(s):
        y_t, cache = mamba2_apply(p, x[:, t:t + 1], F32, cache=cache, **kw)
        outs.append(np.asarray(y_t)[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(y_all), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe(key=0, e=8, k=2, d=16, ff=32, shared=0):
    p = moe_init(jax.random.PRNGKey(key), d, e, ff, shared, ff, glu=True)
    return p


def test_moe_output_shape_and_finite():
    p = _moe()
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 12, 16)).astype(np.float32))
    out = moe_apply(p, x, F32, n_experts=8, top_k=2, capacity_factor=2.0)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))


def test_moe_high_capacity_matches_dense_sum():
    """With capacity >> tokens, output == sum_k gate_k * expert_k(x)."""
    rng = np.random.default_rng(2)
    e, k, d, ff = 4, 2, 8, 16
    p = _moe(3, e, k, d, ff)
    x = jnp.asarray(rng.standard_normal((1, 6, d)).astype(np.float32))
    out = np.asarray(moe_apply(p, x, F32, n_experts=e, top_k=k, capacity_factor=100.0))

    xf = np.asarray(x).reshape(6, d)
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :k]
    ref = np.zeros_like(xf)
    for t in range(6):
        g = probs[t, top[t]]
        g = g / g.sum()
        for j, eid in enumerate(top[t]):
            wg, wu, wd = (np.asarray(p[m][eid]) for m in ("wg", "wu", "wd"))
            h = (xf[t] @ wu) * (jax.nn.silu(xf[t] @ wg))
            ref[t] += g[j] * np.asarray(h @ wd)
    np.testing.assert_allclose(out.reshape(6, d), ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop tokens (outputs partially zeroed), not crash."""
    p = _moe(4)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 32, 16)).astype(np.float32))
    out = moe_apply(p, x, F32, n_experts=8, top_k=2, capacity_factor=0.1)
    assert np.all(np.isfinite(np.asarray(out)))


def test_moe_shared_experts_add():
    p = _moe(5, shared=2)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((1, 8, 16)).astype(np.float32))
    out = moe_apply(p, x, F32, n_experts=8, top_k=2)
    p2 = dict(p)
    del p2["shared"]
    out2 = moe_apply(p2, x, F32, n_experts=8, top_k=2)
    assert not np.allclose(np.asarray(out), np.asarray(out2))


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def test_rope_relative_position_property():
    """<q_i, k_j> depends only on i - j after RoPE."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 10, 1, 8)).astype(np.float32))
    pos = jnp.arange(10)[None, :]
    qr = np.asarray(apply_rope(q, pos, 10_000.0))
    k = qr[0, :, 0, :]
    d03 = float(k[0] @ k[3])
    d25 = float(k[2] @ k[5])
    # same underlying vector rotated: <r(x,i), r(x,j)> = f(i-j)
    assert abs(d03 - d25) < 1e-6 or True  # vectors differ; test with same base below
    base = jnp.asarray(np.tile(rng.standard_normal((1, 1, 1, 8)).astype(np.float32), (1, 10, 1, 1)))
    br = np.asarray(apply_rope(base, pos, 10_000.0))[0, :, 0, :]
    assert abs(br[0] @ br[3] - br[2] @ br[5]) < 1e-4


def test_mrope_text_equals_standard_rope():
    """Equal (t,h,w) position ids == standard RoPE (text-only input)."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 6, 2, 16)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 6))
    std = np.asarray(apply_rope(x, pos, 10_000.0))
    mro = np.asarray(apply_rope(x, pos3, 10_000.0, sections=(2, 3, 3)))
    np.testing.assert_allclose(std, mro, rtol=1e-6, atol=1e-6)


def test_causal_mask_offset():
    m = np.asarray(causal_mask(2, 6, q_offset=4))
    assert m[0, :5].all() and not m[0, 5]
    assert m[1].all()


# ---------------------------------------------------------------------------
# chunked CE
# ---------------------------------------------------------------------------

def test_chunked_ce_matches_direct():
    from repro.configs.base import ModelConfig
    from repro.models.transformer import lm_loss_chunked

    cfg = ModelConfig(vocab=50, d_model=16, numerics=F32)
    rng = np.random.default_rng(7)
    hidden = jnp.asarray(rng.standard_normal((2, 24, 16)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 50, (2, 24)).astype(np.int32))
    w = jnp.asarray(rng.standard_normal((16, 50)).astype(np.float32))
    params = {"unembed": w}
    import dataclasses
    cfg = dataclasses.replace(cfg, tie_embeddings=False)
    l_chunked = float(lm_loss_chunked(cfg, params, hidden, labels, chunk=7))
    logits = np.asarray(hidden) @ np.asarray(w)
    lse = jax.nn.logsumexp(jnp.asarray(logits), axis=-1)
    gold = np.take_along_axis(logits, np.asarray(labels)[..., None], -1)[..., 0]
    l_direct = float(jnp.mean(lse - gold))
    assert abs(l_chunked - l_direct) < 1e-4


def test_chunked_ce_masks_negative_labels():
    from repro.configs.base import ModelConfig
    from repro.models.transformer import lm_loss_chunked
    import dataclasses

    cfg = dataclasses.replace(ModelConfig(vocab=50, d_model=16, numerics=F32), tie_embeddings=False)
    rng = np.random.default_rng(8)
    hidden = jnp.asarray(rng.standard_normal((1, 8, 16)).astype(np.float32))
    labels = jnp.asarray(np.array([[-1, -1, 3, 4, 5, 6, 7, 8]], dtype=np.int32))
    params = {"unembed": jnp.asarray(rng.standard_normal((16, 50)).astype(np.float32))}
    full = float(lm_loss_chunked(cfg, params, hidden, labels, chunk=4))
    # loss over only the valid suffix must equal the masked full loss
    suffix = float(lm_loss_chunked(cfg, params, hidden[:, 2:], labels[:, 2:], chunk=4))
    assert abs(full - suffix) < 1e-5
