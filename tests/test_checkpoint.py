"""Checkpoint substrate: atomicity, GC, manifest, elastic re-placement."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    t = _tree()
    ckpt.save(d, 3, t, extra={"note": "hi"})
    out, manifest = ckpt.restore(d, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 3 and manifest["extra"]["note"] == "hi"


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, t, keep=3)
    assert ckpt.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 3 and kept[0] == "step_00000003"


def test_atomic_no_partial_state(tmp_path):
    """A tmp dir left behind by a crash must never be picked up."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    os.makedirs(os.path.join(d, ".tmp_crashed"), exist_ok=True)
    with open(os.path.join(d, ".tmp_crashed", "arrays.npz"), "w") as f:
        f.write("garbage")
    assert ckpt.latest_step(d) == 1
    out, _ = ckpt.restore(d, jax.tree.map(jnp.zeros_like, _tree()))
    assert out is not None


def test_structure_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    bad = {"a": jnp.zeros((16, 8))}  # fewer leaves
    with pytest.raises(AssertionError):
        ckpt.restore(d, bad)


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree())
    bad = jax.tree.map(jnp.zeros_like, _tree())
    bad["a"] = jnp.zeros((8, 16))
    with pytest.raises(AssertionError):
        ckpt.restore(d, bad)


def test_async_save(tmp_path):
    d = str(tmp_path)
    th = ckpt.save_async(d, 7, _tree())
    th.join(timeout=30)
    assert ckpt.latest_step(d) == 7


def test_elastic_restore_replacement(tmp_path):
    """Restore with explicit shardings (new-mesh placement path)."""
    d = str(tmp_path)
    t = _tree()
    ckpt.save(d, 2, t)
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out, _ = ckpt.restore(d, jax.tree.map(jnp.zeros_like, t), shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
