"""Differential conformance suite: the oracle matrix must agree.

Four independent formulations of the posit/PLAM numerics (pure-Python
golden, vectorized JAX bit kernels, exhaustive-table codec, Pallas
kernels) are compared per-op:

* committed golden vectors under ``tests/vectors/`` (the fast drift
  gate — regenerate with ``python -m repro.conformance gen``),
* exhaustive all-pairs multiplier sweeps vs golden for small n,
* bit-identical Pallas matmul parity on ragged/tile-boundary shapes,
* the paper's Sec. III-C error-model claims (eq. 24) promoted from
  ``benchmarks/error_analysis.py`` into asserted tests,
* metamorphic properties through the hypothesis shim, and
* fault-injection meta-tests: a single flipped bit in ANY layer must
  be caught by the fuzzer and shrunk to a minimal reproducer.

``REPRO_PROP_MULT`` scales the drawn-example budgets (CI stress lane).
"""
import os
import pathlib
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.conformance import (
    FaultyImpl,
    GoldenImpl,
    boundary_patterns,
    check_vectors,
    default_impls,
    outputs_equal,
    run_fuzz,
    shrink_pair,
)
from repro.conformance.shrink import describe_pattern, reproducer
from repro.conformance.vectors import VECTOR_DIR, pair_grid, plan
from repro.kernels import plam_matmul_bits
from repro.kernels.ref import plam_matmul_ref, plam_matmul_seqref
from repro.numerics import PositSpec, plam_relative_error
from repro.numerics.plam import exact_mul, plam_mul

_MULT = int(os.environ.get("REPRO_PROP_MULT", "1"))

SWEEP_SPECS = [PositSpec(6, 0), PositSpec(8, 0), PositSpec(8, 1),
               PositSpec(10, 1)]


# ---------------------------------------------------------------- vectors

def test_committed_vectors_present_and_green():
    """Every planned vector file exists and every impl reproduces it."""
    assert VECTOR_DIR.is_dir(), (
        f"{VECTOR_DIR} missing — run `python -m repro.conformance gen`")
    failures = check_vectors()
    assert not failures, "\n".join(failures)


def test_vector_plan_covers_spec_matrix():
    items = plan()
    specs = {(i["n"], i["es"]) for i in items}
    assert (16, 1) in specs, "the headline P16 spec must be pinned"
    assert all((n, es) in specs for n, es in [(6, 0), (8, 0), (8, 1), (10, 1)])
    ops = {i["op"] for i in items}
    assert ops == {"plam_mul", "exact_mul", "decode"}


# ------------------------------------------- exhaustive multiplier sweeps

@pytest.mark.parametrize("spec", SWEEP_SPECS, ids=str)
@pytest.mark.parametrize("op", ["plam_mul", "exact_mul"])
def test_exhaustive_mul_jax_vs_golden(spec, op):
    """ALL bit pairs: the JAX multiplier == the pure-Python golden model."""
    pa, pb = pair_grid(spec.n)
    fn = plam_mul if op == "plam_mul" else exact_mul
    jax_out = np.asarray(fn(pa, pb, spec)) & spec.mask_n
    gold = GoldenImpl().run(op, (pa, pb), spec) & spec.mask_n
    bad = jax_out != gold
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        raise AssertionError(
            f"{op} {spec}: first mismatch at pair "
            f"({describe_pattern(int(pa[i]), spec)}; "
            f"{describe_pattern(int(pb[i]), spec)}): "
            f"jax {jax_out[i]:#x} vs golden {gold[i]:#x} "
            f"[{bad.sum()} total]")


@pytest.mark.slow
@pytest.mark.parametrize("op", ["plam_mul", "exact_mul"])
def test_exhaustive_mul_n12_jax_vs_golden(op):
    """16.7M-pair sweep for Posit<12,1> (slow lane)."""
    spec = PositSpec(12, 1)
    pa, pb = pair_grid(spec.n)
    fn = plam_mul if op == "plam_mul" else exact_mul
    jax_out = np.asarray(fn(pa, pb, spec)) & spec.mask_n
    gold = GoldenImpl().run(op, (pa, pb), spec) & spec.mask_n
    assert np.array_equal(jax_out, gold), f"{op} {spec}: sweep diverged"


# ------------------------------------------------- Pallas matmul parity

RAGGED_SHAPES = [
    (4, 5, 3),      # K not a block multiple
    (1, 7, 1),      # M = N = 1
    (3, 130, 9),    # K just past one 128-block
    (9, 257, 5),    # K spans three blocks with a ragged tail
    (2, 1, 2),      # K = 1
    (17, 64, 33),   # ragged M and N
]


@pytest.mark.parametrize("shape", RAGGED_SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_plam_matmul_bit_identical_to_seqref(shape):
    """Pallas matmul == sequential-k reference, bit for bit, on shapes
    that exercise the zero-padding paths (ragged K/M/N, unit dims)."""
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) & 0xFFFF)
    spec = PositSpec(16, 1)
    a = rng.integers(0, 1 << 16, (m, k)).astype(np.int32)
    b = rng.integers(0, 1 << 16, (k, n)).astype(np.int32)
    a.flat[:: max(1, a.size // 7)] = spec.nar  # NaR lanes must mask to 0
    b.flat[:: max(1, b.size // 5)] = 0
    want = np.asarray(plam_matmul_seqref(a, b, spec))
    got = np.asarray(plam_matmul_bits(a, b, spec, interpret=True))
    assert np.array_equal(want.view(np.uint32), got.view(np.uint32)), (
        f"shape {shape}: kernel diverged from sequential reference")


def test_plam_matmul_seqref_close_to_sum_ref():
    """The two references agree to f32 reduction-order noise."""
    rng = np.random.default_rng(7)
    spec = PositSpec(16, 1)
    a = rng.integers(0, 1 << 16, (8, 40)).astype(np.int32)
    b = rng.integers(0, 1 << 16, (40, 6)).astype(np.int32)
    np.testing.assert_allclose(
        np.asarray(plam_matmul_seqref(a, b, spec)),
        np.asarray(plam_matmul_ref(a, b, spec)),
        rtol=1e-5, atol=1e-30)


@pytest.mark.skipif(
    __import__("jax").default_backend() != "tpu",
    reason="compiled Pallas needs a TPU backend")
def test_plam_matmul_compiled_matches_interpret():
    rng = np.random.default_rng(3)
    spec = PositSpec(16, 1)
    a = rng.integers(0, 1 << 16, (9, 130)).astype(np.int32)
    b = rng.integers(0, 1 << 16, (130, 5)).astype(np.int32)
    ci = np.asarray(plam_matmul_bits(a, b, spec, interpret=True))
    cc = np.asarray(plam_matmul_bits(a, b, spec, interpret=False))
    assert np.array_equal(ci.view(np.uint32), cc.view(np.uint32))


# -------------------------------------------- error model (paper eq. 24)

def _error_analysis():
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks import error_analysis
    return error_analysis


def test_eq24_bound_and_argmax_on_fraction_grid():
    """Empirical error grid obeys eq. (24): max 1/9 at fa = fb = 0.5."""
    ea = _error_analysis()
    fa, fb, err = ea.error_grid(n=64)
    assert err.max() <= 1 / 9 + 1e-6, f"error {err.max()} exceeds 1/9 bound"
    am = np.unravel_index(err.argmax(), err.shape)
    assert abs(fa[am[0]] - 0.5) <= 1 / 64 and abs(fb[am[1]] - 0.5) <= 1 / 64
    # independently-written eq. (24) vs the grid, pointwise: without a
    # fraction carry the approximation is 1+fa+fb (error fa*fb/exact);
    # with a carry it is 2(fa+fb) (error (1-fa)(1-fb)/exact)
    ga, gb = fa[:, None], fb[None, :]
    exact = (1 + ga) * (1 + gb)
    analytic = np.where(ga + gb < 1,
                        ga * gb / exact,
                        (1 - ga) * (1 - gb) / exact)
    np.testing.assert_allclose(err, analytic, atol=2e-4)


def test_error_scale_independence():
    """Same fractions across regimes/exponents -> identical error."""
    ea = _error_analysis()
    errs = ea.scale_independence(trials=32)
    assert float(errs.std()) <= 1e-7, (
        f"PLAM error varied with scale fields: std={errs.std():.3e}")


def test_dnn_distribution_mean_error_band():
    """N(0,1) operands land in the paper's few-percent mean-error regime."""
    ea = _error_analysis()
    err = ea.dnn_distribution_error(n=20_000)
    assert 0.0 <= float(err.mean()) <= 0.08
    assert float(err.max()) <= 1 / 9 + 1e-6


@settings(max_examples=50 * _MULT, deadline=None)
@given(st.integers(0, (1 << 16) - 1), st.integers(0, (1 << 16) - 1))
def test_relative_error_bound_property(pa, pb):
    spec = PositSpec(16, 1)
    err = float(np.asarray(
        plam_relative_error(np.int32([pa]), np.int32([pb]), spec))[0])
    assert -1e-6 <= err <= 1 / 9 + 1e-6


# ------------------------------------------------ metamorphic properties

@settings(max_examples=40 * _MULT, deadline=None)
@given(st.sampled_from([(8, 0), (10, 1), (16, 1)]),
       st.integers(0, (1 << 16) - 1), st.integers(0, (1 << 16) - 1))
def test_mul_commutes_across_impls(spec_ne, pa, pb):
    spec = PositSpec(*spec_ne)
    pa &= spec.mask_n
    pb &= spec.mask_n
    impls = default_impls(spec)
    for name, im in impls.items():
        for op in ("plam_mul", "exact_mul"):
            if op not in im.ops(spec):
                continue
            ab = im.run(op, (np.int32([pa]), np.int32([pb])), spec)
            ba = im.run(op, (np.int32([pb]), np.int32([pa])), spec)
            assert outputs_equal(ab, ba).all(), (
                f"{name}.{op} not commutative on ({pa:#x}, {pb:#x})")


@settings(max_examples=40 * _MULT, deadline=None)
@given(st.integers(0, (1 << 16) - 1))
def test_nar_absorbs_and_one_is_identity(p):
    spec = PositSpec(16, 1)
    p &= spec.mask_n
    one = 1 << (spec.n - 2)
    impls = default_impls(spec)
    for name, im in impls.items():
        for op in ("plam_mul", "exact_mul"):
            if op not in im.ops(spec):
                continue
            out = im.run(op, (np.int32([p]), np.int32([spec.nar])), spec)
            assert (np.asarray(out, np.int64) & spec.mask_n == spec.nar).all(), (
                f"{name}.{op}: NaR not absorbing for {p:#x}")
            out = im.run("exact_mul", (np.int32([p]), np.int32([one])), spec) \
                if op == "exact_mul" else None
            if out is not None:
                assert (np.asarray(out, np.int64) & spec.mask_n == p).all(), (
                    f"{name}: x * 1 != x for {p:#x}")


def test_boundary_patterns_cover_edges():
    spec = PositSpec(8, 0)
    pats = set(int(p) for p in boundary_patterns(spec))
    assert {0, spec.nar, 1, 1 << (spec.n - 2)} <= pats
    assert all(0 <= p <= spec.mask_n for p in pats)


# ------------------------------------------------------- fuzz (fast run)

def test_fuzz_small_budget_is_clean():
    """A small seeded fuzz across two specs finds no disagreements."""
    report = run_fuzz(specs=(PositSpec(8, 0),), seed=3, count=128,
                      modes=("uniform", "boundary"))
    assert report.ok, report.summary()
    assert report.checked > 0


# ------------------------------------------------------- fault injection

FAULT_PLANS = [
    ("golden", "exact_mul", 0),
    ("jax", "plam_mul", 2),
    ("table", "plam_mul", 0),
    ("pallas_interp", "decode", 7),
]


@pytest.mark.parametrize("layer,op,bit", FAULT_PLANS,
                         ids=[f"{p[0]}.{p[1]}" for p in FAULT_PLANS])
def test_single_bit_fault_is_caught_and_shrunk(layer, op, bit):
    """Flipping one output bit in ANY layer must be detected by the
    differential fuzzer and reduced to a minimal reproducer."""
    spec = PositSpec(8, 0)
    impls = default_impls(spec)
    impls[layer] = FaultyImpl(impls[layer], op, bit=bit)
    report = run_fuzz(specs=(spec,), seed=1, count=256, impls=impls,
                      modes=("uniform",))
    assert not report.ok, f"fault in {layer}.{op} went undetected"
    caught = [m for m in report.mismatches
              if layer in m.impl_a or layer in m.impl_b]
    assert caught, f"mismatches found but none attributed to {layer}"
    shrunk = [m for m in caught if m.report]
    assert shrunk, "no shrunk reproducer attached"
    rep = shrunk[0].report
    assert "CONFORMANCE MISMATCH" in rep
    assert "def test_regression_" in rep, "missing paste-ready snippet"


def test_faulty_impl_trigger_gates_the_fault():
    spec = PositSpec(8, 0)
    base = default_impls(spec)["jax"]
    faulty = FaultyImpl(base, "plam_mul", bit=0,
                        trigger=lambda a, b: np.zeros(np.shape(a), bool))
    pa = np.int32([12]); pb = np.int32([34])
    assert outputs_equal(
        faulty.run("plam_mul", (pa, pb), spec),
        base.run("plam_mul", (pa, pb), spec)).all()


# ------------------------------------------------------------- shrinker

def test_shrink_pair_reaches_minimal_pair():
    """A predicate true whenever bit 0 of pa is set shrinks to (1, 0)."""
    pa, pb = shrink_pair(lambda a, b: bool(a & 1), 0xB7, 0x5D, 8)
    assert pa == 1 and pb == 0


def test_shrink_pair_respects_joint_predicate():
    pred = lambda a, b: (a & 0x80) != 0 and (b & 0x80) != 0  # noqa: E731
    pa, pb = shrink_pair(pred, 0xFF, 0xD3, 8)
    assert pred(pa, pb)
    assert bin(pa).count("1") == 1 and bin(pb).count("1") == 1


def test_describe_pattern_fields():
    spec = PositSpec(8, 0)
    assert describe_pattern(0, spec).endswith("zero")
    assert describe_pattern(spec.nar, spec).endswith("NaR")
    line = describe_pattern(1 << 6, spec)  # +1.0
    assert "value 1" in line and "k=0" in line


def test_reproducer_snippet_is_paste_ready():
    from repro.conformance.fuzz import Mismatch
    spec = PositSpec(8, 0)
    mm = Mismatch(op="plam_mul", spec=spec, impl_a="golden", impl_b="table",
                  inputs=(0x40, 0x41), out_a=0x41, out_b=0x42, count=1)
    text = reproducer(mm, spec)
    assert "from repro.conformance import default_impls" in text
    assert "outputs_equal" in text and "PositSpec(8, 0)" in text


# --------------------------------------------------------------- oracles

def test_oracle_matrix_ops_cover_contract():
    """Every default impl exposes a coherent subset of the op set."""
    spec = PositSpec(16, 1)
    impls = default_impls(spec)
    assert {"golden", "jax", "jax_logfix", "table", "pallas_interp"} <= set(impls)
    for name, im in impls.items():
        ops = im.ops(spec)
        assert ops, f"{name} exposes no ops"
        assert set(ops) <= {"encode", "decode", "quantize", "exact_mul",
                            "plam_mul"}
    assert set(impls["golden"].ops(spec)) == {
        "encode", "decode", "quantize", "exact_mul", "plam_mul"}


def test_encode_subnormal_regression():
    """Regression for the DAZ bug the fuzzer caught: an f32-subnormal
    input must encode to minpos (never to zero) in EVERY layer."""
    x = np.float32([9.99994610111476e-41, -9.99994610111476e-41])
    for spec in (PositSpec(8, 0), PositSpec(16, 1)):
        impls = default_impls(spec)
        want = np.array([1, spec.mask_n], np.int64)
        for name, im in impls.items():
            if "encode" not in im.ops(spec):
                continue
            got = np.asarray(im.run("encode", (x,), spec), np.int64) & spec.mask_n
            assert np.array_equal(got, want), (
                f"{name}.encode flushed a subnormal to {got} (want {want})")
