"""Validate the trip-count-corrected HLO analyzer against XLA's own
cost model on loop-free programs, and against hand counts on loops."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _cost(c):
    """compiled.cost_analysis() returns a per-program list on some JAX
    versions and a bare dict on others."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_loopfree_matmul_matches_cost_analysis():
    c = _compile(lambda x, w: jnp.tanh(x @ w),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32))
    a = analyze(c.as_text())
    assert a.flops == _cost(c)["flops"] == 2 * 512 ** 3


def test_scan_flops_scale_with_trip_count():
    def make(n):
        def f(x, w):
            def body(c_, _):
                return jnp.tanh(c_ @ w), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                        jax.ShapeDtypeStruct((256, 256), jnp.float32))

    a4 = analyze(make(4).as_text())
    a8 = analyze(make(8).as_text())
    assert a4.flops == 4 * 2 * 256 ** 3
    assert a8.flops == 8 * 2 * 256 ** 3
    # XLA's raw cost_analysis does NOT scale (the bug we correct):
    assert _cost(make(4))["flops"] == _cost(make(8))["flops"]


def test_nested_scan_multipliers_compose():
    def f(x, w):
        def inner(c_, _):
            return c_ @ w, None

        def outer(c_, _):
            o, _ = jax.lax.scan(inner, c_, None, length=3)
            return o, None

        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    assert analyze(c.as_text()).flops == 12 * 2 * 128 ** 3


def test_conv_flops():
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    c = _compile(f, jax.ShapeDtypeStruct((2, 16, 16, 8), jnp.float32),
                 jax.ShapeDtypeStruct((3, 3, 8, 16), jnp.float32))
    assert analyze(c.as_text()).flops == 2 * (2 * 16 * 16 * 16) * (3 * 3 * 8)


def test_hbm_bytes_scale_with_loop():
    def make(n):
        def f(x):
            def body(c_, _):
                return c_ * 2.0 + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return _compile(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))

    b2 = analyze(make(2).as_text()).hbm_bytes
    b8 = analyze(make(8).as_text()).hbm_bytes
    assert b8 > 3 * b2  # roughly linear in trip count


def test_elem_ops_counted():
    c = _compile(lambda x: jnp.tanh(x) * 2.0, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    a = analyze(c.as_text())
    assert a.elem_ops >= 128 * 128  # at least the fused elementwise result
