"""Preemptive scheduling (PR 6 acceptance bar).

Preemption is an EXECUTION STRATEGY, not a model: a request evicted
under pool pressure and later resumed by recomputing its committed
context must produce exactly the greedy tokens an uninterrupted run
produces, across chunked/unchunked prefill, spec_k on/off and tp=1/2
(the tp=2 cases run in a subprocess with forced host devices, like
tests/test_tp_chunked_serving.py).  Alongside token identity this file
pins the preemption-path scrub (extends the PR 4 aliasing regression:
a victim's blocks — committed K/V included — read as zeros once freed),
deadline-expiry cancellation driven by an injected fake clock,
priority-ordered victim selection, and client-side cancel.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.models import build
from repro.serving import (
    ContinuousBatchingEngine,
    PagedServeConfig,
    RequestState,
)

CFG = ModelConfig(
    name="toy-preempt", family="dense", n_layers=2, d_model=32, n_heads=4,
    n_kv=2, head_dim=8, d_ff=64, vocab=61,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def params():
    return build(CFG).init(jax.random.PRNGKey(0))


def _reference(params, prompt, *, max_new=12, chunk=0, spec=0):
    """Uninterrupted run: a pool big enough that nothing is evicted."""
    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=2,
                              max_seq_len=32, prefill_chunk=chunk,
                              spec_k=spec))
    r = eng.submit(prompt, max_new_tokens=max_new)
    out = eng.run()[r.rid]
    assert eng.stats.preemptions == 0
    return out


def _pressure_engine(params, *, chunk=0, spec=0, num_blocks=8, max_slots=2):
    """A pool with room for roughly one full-length sequence: two
    concurrent max-length requests MUST collide and force evictions."""
    return ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=num_blocks,
                              max_slots=max_slots, max_seq_len=32,
                              preemption="recompute",
                              prefill_chunk=chunk, spec_k=spec))


# ---------------------------------------------------------------------------
# token identity across the config matrix (tp=1 half; tp=2 is below)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [0, 2])
@pytest.mark.parametrize("chunk", [0, 4])
def test_preempted_stream_token_identical(params, chunk, spec):
    """Two max-length requests on a pressure pool: the less deserving
    one is evicted mid-decode (possibly repeatedly), resumed by
    recompute, and still emits exactly the uninterrupted token stream."""
    rng = np.random.default_rng(0)
    pa = rng.integers(0, 61, 8).tolist()
    pb = rng.integers(0, 61, 8).tolist()
    expect_a = _reference(params, pa, chunk=chunk, spec=spec)
    expect_b = _reference(params, pb, chunk=chunk, spec=spec)

    eng = _pressure_engine(params, chunk=chunk, spec=spec)
    a = eng.submit(pa, max_new_tokens=12)
    b = eng.submit(pb, max_new_tokens=12, arrival_step=1)
    done = eng.run()
    assert eng.stats.preemptions > 0, "pool pressure never forced an eviction"
    assert done[a.rid] == expect_a, f"survivor diverged (chunk={chunk} spec={spec})"
    assert done[b.rid] == expect_b, f"victim diverged (chunk={chunk} spec={spec})"
    # the earlier arrival is more deserving: it is never the victim
    assert a.preempt_count == 0 and b.preempt_count > 0
    # nothing was cancelled, so every eviction was eventually resumed,
    # each with a recorded latency of at least one parked step
    assert eng.stats.resumes == eng.stats.preemptions
    assert len(eng.stats.resume_latency_steps) == eng.stats.resumes
    assert all(s >= 1 for s in eng.stats.resume_latency_steps)
    # no leak: the whole pool is back on the free list
    assert eng.allocator.num_free == 7
    assert not eng.scheduler.has_work()


# ---------------------------------------------------------------------------
# scrub regression on the preemption path
# ---------------------------------------------------------------------------

def test_preempted_blocks_scrubbed_before_reuse(params):
    """PR 4's aliasing regression, extended to preemption: evicting a
    victim frees EVERY block it wrote — committed K/V included, since
    the resume recomputes it — and the engine must scrub them all, or
    the free list would hand a future sequence blocks still holding the
    victim's keys.  Right after the step that evicted b, every
    free-listed block must read as zeros (spec_k=2 so rolled-back draft
    tails are in the mix too)."""
    rng = np.random.default_rng(5)
    eng = _pressure_engine(params, spec=2)
    a = eng.submit(rng.integers(0, 61, 8).tolist(), max_new_tokens=12)
    b = eng.submit(rng.integers(0, 61, 8).tolist(), max_new_tokens=12,
                   arrival_step=1)
    steps = 0
    while b.preempt_count == 0 and steps < 200:
        eng.step()
        steps += 1
    assert b.state is RequestState.PREEMPTED, "pressure never evicted b"
    free = list(eng.allocator._free)
    assert free, "eviction must have returned blocks"
    kp = np.asarray(eng._k_pool)
    vp = np.asarray(eng._v_pool)
    assert float(np.abs(kp[:, free]).sum()) == 0.0, (
        "freed blocks still hold the victim's keys")
    assert float(np.abs(vp[:, free]).sum()) == 0.0, (
        "freed blocks still hold the victim's values")
    # teeth: the survivor's owned blocks ARE nonzero — the scrub is
    # selective, not a pool-wide wipe
    assert a.state is RequestState.RUNNING
    assert float(np.abs(kp[:, a.alloc.blocks]).sum()) > 0.0
    eng.run()
    assert eng.allocator.num_free == 7


# ---------------------------------------------------------------------------
# deadlines (injected fake clock)
# ---------------------------------------------------------------------------

def test_deadline_expiry_cancels_and_keeps_partial_output(params):
    """A running request whose wall-clock budget expires is cancelled
    mid-stream keeping its committed output; a never-admitted request
    with an already-blown deadline is cancelled from the waiting queue
    with no output; the survivor's tokens are unaffected."""
    t = [0.0]
    rng = np.random.default_rng(9)
    pa = rng.integers(0, 61, 6).tolist()
    pb = rng.integers(0, 61, 6).tolist()
    expect_a = _reference(params, pa, max_new=10)

    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=2,
                              max_seq_len=32, preemption="recompute",
                              clock=lambda: t[0]))
    a = eng.submit(pa, max_new_tokens=10)
    b = eng.submit(pb, max_new_tokens=10, deadline_s=5.0)
    c = eng.submit(rng.integers(0, 61, 4).tolist(), max_new_tokens=4,
                   deadline_s=0.5)  # slots are full: expires while WAITING
    for _ in range(4):
        eng.step()
        t[0] += 1.0
    assert b.state is RequestState.RUNNING and len(b.output) > 0
    t[0] = 10.0  # blow b's budget (c's expired during the warm-up steps)
    done = eng.run()
    assert b.state is RequestState.CANCELLED
    assert c.state is RequestState.CANCELLED and c.output == []
    assert eng.stats.deadline_cancelled == 2
    assert 0 < len(done[b.rid]) < 10, "committed output must survive cancel"
    assert done[a.rid] == expect_a
    assert eng.allocator.num_free == 63


# ---------------------------------------------------------------------------
# priority-ordered victim selection
# ---------------------------------------------------------------------------

def test_high_priority_preempts_running_low_priority(params):
    """A later-arriving high-priority request evicts the running
    low-priority victim at admission, finishes first, and the victim
    resumes to its exact uninterrupted stream."""
    rng = np.random.default_rng(13)
    pl = rng.integers(0, 61, 8).tolist()
    ph = rng.integers(0, 61, 8).tolist()
    expect_l = _reference(params, pl, max_new=8)
    expect_h = _reference(params, ph, max_new=4)

    # 4 free blocks: low alone needs all of them at full length, so
    # high (3 blocks worst case) cannot be admitted without an eviction
    eng = _pressure_engine(params, num_blocks=5)
    low = eng.submit(pl, max_new_tokens=8)
    high = eng.submit(ph, max_new_tokens=4, arrival_step=2, priority=5)
    done = eng.run()
    assert low.preempt_count >= 1, "low-priority request was never evicted"
    assert high.preempt_count == 0, "high priority must be eviction-immune"
    assert high.finished_step < low.finished_step
    assert done[low.rid] == expect_l
    assert done[high.rid] == expect_h
    assert eng.allocator.num_free == 4


# ---------------------------------------------------------------------------
# client-side cancel
# ---------------------------------------------------------------------------

def test_client_cancel_releases_blocks(params):
    """engine.cancel() mid-stream (preemption OFF — cancel works in
    both regimes): the stream stops with its committed output, its
    blocks return to the pool, and the waiting request that inherits
    them still produces its exact solo tokens."""
    rng = np.random.default_rng(17)
    pa = rng.integers(0, 61, 8).tolist()
    pb = rng.integers(0, 61, 6).tolist()
    expect_b = _reference(params, pb, max_new=6)

    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=5, max_slots=1,
                              max_seq_len=16))
    a = eng.submit(pa, max_new_tokens=8)  # 4 blocks: the whole pool
    b = eng.submit(pb, max_new_tokens=6)  # must wait for a's blocks
    for _ in range(3):
        eng.step()
    assert a.state is RequestState.RUNNING
    assert b.state is RequestState.WAITING
    eng.cancel(a)
    assert a.state is RequestState.CANCELLED and a.alloc is None
    assert 0 < len(a.output) < 8
    eng.cancel(a)  # idempotent no-op on a terminal state
    done = eng.run()
    assert done[b.rid] == expect_b
    assert eng.allocator.num_free == 4
    assert eng.stats.deadline_cancelled == 0  # client aborts are not misses


# ---------------------------------------------------------------------------
# tp=2 half of the matrix (forced host devices, subprocess)
# ---------------------------------------------------------------------------

_TP_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.models import build
    from repro.serving import ContinuousBatchingEngine, PagedServeConfig

    assert len(jax.devices()) >= 2, jax.devices()

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=61,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32")
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    pa = rng.integers(0, 61, 8).tolist()
    pb = rng.integers(0, 61, 8).tolist()

    def run(tp, chunk, spec, num_blocks, preemption):
        eng = ContinuousBatchingEngine(cfg, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=num_blocks,
                                  max_slots=2, max_seq_len=32, tp=tp,
                                  prefill_chunk=chunk, spec_k=spec,
                                  preemption=preemption))
        a = eng.submit(pa, max_new_tokens=12)
        b = eng.submit(pb, max_new_tokens=12, arrival_step=1)
        done = eng.run()
        return [done[a.rid], done[b.rid]], eng

    # unchunked+spec_k=0 and chunked+spec_k=2, each preempted under a
    # sharded pressure pool vs. an uninterrupted tp=1 big-pool run
    for chunk, spec in ((0, 0), (4, 2)):
        base, _ = run(1, chunk, spec, 64, "off")
        tp2, eng = run(2, chunk, spec, 8, "recompute")
        assert eng.stats.preemptions > 0, (chunk, spec)
        assert eng.allocator.num_free == 7, (chunk, spec)
        assert base == tp2, (
            f"preempted tp2 diverged chunk={chunk} spec={spec}: "
            f"{base} vs {tp2}")
    print("PREEMPT-TP2-OK")
""")


@pytest.mark.slow
def test_tp2_preempted_token_identical_forced_devices():
    """Preempt-and-resume under tp=2 sharding (head-sharded KV pool) is
    greedy-token-identical to the uninterrupted tp=1 engine, unchunked
    and chunked+speculative.  Subprocess: the forced device count must
    be set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _TP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PREEMPT-TP2-OK" in proc.stdout
