"""Deterministic stand-in for `hypothesis` when it is not installed.

The container this repo is developed in cannot pip-install, so the
property tests would otherwise fail at collection.  This shim
implements the tiny subset the test-suite uses — ``given``,
``settings`` and the ``integers`` / ``floats`` / ``sampled_from``
strategies — by drawing a fixed number of seeded pseudo-random
examples plus the range boundary cases.  It does NOT shrink or keep a
failure database; with the real ``hypothesis`` installed (see
requirements.txt, as in CI) it is never imported.

Installed by tests/conftest.py via ``sys.modules["hypothesis"]``.
"""
from __future__ import annotations

import math
import zlib
from typing import Any, List, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A strategy = boundary examples + a seeded random sampler."""

    def __init__(self, boundary: Sequence[Any], sample):
        self._boundary = list(boundary)
        self._sample = sample

    def draws(self, rng: np.random.Generator, n: int) -> List[Any]:
        out = list(self._boundary[:n])
        while len(out) < n:
            out.append(self._sample(rng))
        return out


def integers(min_value: int, max_value: int) -> _Strategy:
    boundary = [min_value, max_value]
    if min_value <= 0 <= max_value:
        boundary.append(0)
    if min_value <= 1 <= max_value:
        boundary.append(1)

    def sample(rng):
        return int(rng.integers(min_value, max_value + 1))

    return _Strategy(boundary, sample)


def floats(min_value: float = None, max_value: float = None,
           allow_nan: bool = True, allow_infinity: bool = None,
           width: int = 64) -> _Strategy:
    lo = -1e300 if min_value is None else float(min_value)
    hi = 1e300 if max_value is None else float(max_value)
    if width == 32:
        lo, hi = float(np.float32(lo)), float(np.float32(hi))
    boundary = [lo, hi]
    if lo <= 0.0 <= hi:
        boundary += [0.0]
    for v in (1.0, -1.0):
        if lo <= v <= hi:
            boundary.append(v)

    def sample(rng):
        # log-uniform magnitude sampling: uniform-linear over ±1e12
        # would almost never exercise small magnitudes, and the posit
        # codec's interesting cases live near 1.
        if rng.random() < 0.3:
            v = rng.uniform(lo, hi)
        else:
            mag_hi = max(abs(lo), abs(hi), 1e-30)
            mag_lo = max(min(abs(v) for v in (lo, hi) if v != 0.0), 1e-30) \
                if (lo > 0 or hi < 0) else 1e-30
            e = rng.uniform(math.log10(mag_lo), math.log10(mag_hi))
            v = 10.0 ** e
            if lo < 0 and rng.random() < 0.5:
                v = -v
            v = min(max(v, lo), hi)
        if width == 32:
            v = float(np.float32(v))
        return float(min(max(v, lo), hi))

    return _Strategy(boundary, sample)


def sampled_from(elements: Sequence[Any]) -> _Strategy:
    elements = list(elements)

    def sample(rng):
        return elements[int(rng.integers(0, len(elements)))]

    return _Strategy([elements[0]], sample)


class strategies:  # mirror `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — it would carry fn's signature via
        # __wrapped__, and pytest would then demand fixtures named
        # after the strategy parameters.
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            # seed from the test name (crc32: stable across processes,
            # unlike builtin hash) so every test draws a stable,
            # distinct example stream
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            columns = [s.draws(rng, n) for s in strats]
            for i, example in enumerate(zip(*columns)):
                try:
                    fn(*args, *example, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on shim example {i}: "
                        f"{example!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_shim = True
        return wrapper

    return deco


class HealthCheck:
    all = staticmethod(lambda: [])


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass
