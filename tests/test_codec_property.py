"""Property tests: bit codec (posit.py) ≡ exhaustive-table codec
(table.py) across every supported (n, es) spec with n <= 16.

The two codecs are independent formulations (bit-twiddling pattern-RNE
vs golden-model value-space nearest-ties-to-even-pattern); agreement on
encode, decode and round trips — including the zero / NaR / ±maxpos
edges — is one of the repo's strongest invariants.  Runs through
tests/_hypothesis_shim.py when hypothesis is not installed.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.numerics import (
    PositSpec,
    decode,
    decode_table,
    encode,
    encode_table,
)

# every (n, es) the exhaustive-table codec supports (n <= 16), subject
# to the PositSpec constraints (fbmax >= 1, scale range fits f32)
ALL_SPECS = [
    PositSpec(n, es)
    for n in range(4, 17)
    for es in range(0, 4)
    if n - 3 - es >= 1 and (n - 2) * (1 << es) <= 126
]
# the sweep below samples floats per spec; keep a smaller exhaustive
# core for the pattern round-trip to bound runtime
CORE_SPECS = [
    PositSpec(4, 0), PositSpec(5, 1), PositSpec(6, 2), PositSpec(8, 0),
    PositSpec(8, 1), PositSpec(8, 3), PositSpec(10, 2), PositSpec(12, 1),
    PositSpec(16, 0), PositSpec(16, 1), PositSpec(16, 2), PositSpec(16, 3),
]


def _match(a, b):
    return (a == b) | (np.isnan(a) & np.isnan(b))


def _maxpos(spec):
    return float(2.0 ** spec.max_scale)


@pytest.mark.parametrize("spec", ALL_SPECS, ids=str)
def test_edges_zero_nar_maxpos(spec):
    """zero / NaR / ±maxpos agree between both codecs."""
    maxpos = _maxpos(spec)
    xs = jnp.asarray(
        np.array([0.0, -0.0, np.nan, np.inf, -np.inf,
                  maxpos, -maxpos, 10 * maxpos, -10 * maxpos,
                  1 / maxpos, -1 / maxpos, 0.1 / maxpos], np.float32))
    eb = np.asarray(encode(xs, spec)) & spec.mask_n
    et = np.asarray(encode_table(xs, spec)) & spec.mask_n
    assert np.array_equal(eb, et)
    assert eb[0] == 0 and eb[1] == 0  # ±0 -> zero pattern
    assert eb[2] == spec.nar and eb[3] == spec.nar and eb[4] == spec.nar
    assert eb[5] == spec.maxpos_body  # maxpos encodes to maxpos
    assert eb[7] == spec.maxpos_body  # saturation, never NaR
    assert eb[9] == 1  # minpos
    assert eb[11] == 1  # underflow saturates to minpos, never to zero
    pats = jnp.asarray(
        np.array([0, spec.nar, spec.maxpos_body, 1,
                  (-spec.maxpos_body) & spec.mask_n,
                  (-1) & spec.mask_n], np.int32))
    db = np.asarray(decode(pats, spec), np.float64)
    dt = np.asarray(decode_table(pats, spec), np.float64)
    assert _match(db, dt).all()
    assert db[0] == 0.0 and np.isnan(db[1])
    assert db[2] == maxpos and db[4] == -maxpos


@pytest.mark.parametrize("spec", CORE_SPECS, ids=str)
def test_exhaustive_pattern_round_trip_both_codecs(spec):
    """For EVERY pattern: table decode == bit decode, and both codecs
    re-encode the decoded value back to the same pattern (bijection)."""
    pats = np.arange(1 << spec.n, dtype=np.int32)
    jp = jnp.asarray(pats)
    db = np.asarray(decode(jp, spec))
    dt = np.asarray(decode_table(jp, spec))
    assert _match(db, dt).all()
    rb = np.asarray(encode(jnp.asarray(db), spec)) & spec.mask_n
    rt = np.asarray(encode_table(jnp.asarray(dt), spec)) & spec.mask_n
    assert np.array_equal(rb, pats & spec.mask_n)
    assert np.array_equal(rt, pats & spec.mask_n)


@settings(max_examples=60, deadline=None)
@given(
    st.sampled_from(ALL_SPECS),
    st.floats(min_value=-1e30, max_value=1e30, allow_nan=False, width=32),
)
def test_property_encode_agrees(spec, x):
    xs = jnp.float32(x)
    eb = int(encode(xs, spec)) & spec.mask_n
    et = int(encode_table(xs, spec)) & spec.mask_n
    assert eb == et, (spec, x, hex(eb), hex(et))


@settings(max_examples=60, deadline=None)
@given(
    st.sampled_from(ALL_SPECS),
    st.floats(min_value=-1e30, max_value=1e30, allow_nan=False, width=32),
)
def test_property_quantize_round_trip_agrees(spec, x):
    """decode(encode(x)) is identical through either codec, and
    re-encoding the quantized value is a fixed point (idempotence)."""
    xs = jnp.float32(x)
    qb = float(decode(encode(xs, spec), spec))
    qt = float(decode_table(encode_table(xs, spec), spec))
    assert qb == qt or (np.isnan(qb) and np.isnan(qt)), (spec, x, qb, qt)
    rb = int(encode(jnp.float32(qb), spec)) & spec.mask_n
    assert rb == int(encode(xs, spec)) & spec.mask_n


@settings(max_examples=40, deadline=None)
@given(
    st.sampled_from(ALL_SPECS),
    st.integers(min_value=0, max_value=(1 << 16) - 1),
)
def test_property_pattern_decode_agrees(spec, pat):
    pat &= spec.mask_n
    db = float(decode(jnp.int32(pat), spec))
    dt = float(decode_table(jnp.int32(pat), spec))
    assert db == dt or (np.isnan(db) and np.isnan(dt)), (spec, hex(pat))
