"""Sharding rules: parameter specs, sanitization, logical-axis mapping."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    constrain,
    param_shardings,
    pspec,
    sanitize,
    spec_for_param,
    use_mesh,
)


def test_spec_rules():
    assert spec_for_param("layers/attn/wq", 3) == (None, None, "model")
    assert spec_for_param("layers/attn/wo", 3) == (None, "model", None)
    assert spec_for_param("embed", 2) == ("model", None)
    assert spec_for_param("unembed", 2) == (None, "model")
    assert spec_for_param("layers/mlp/wd", 3) == (None, "model", None)
    assert spec_for_param("layers/moe/wu", 4) == (None, None, None, "model")
    assert spec_for_param("layers/moe/router", 3) == (None, None, None)
    assert spec_for_param("layers/mamba/in_proj", 3) == (None, None, "model")
    assert spec_for_param("ln_f/scale", 1) == (None,)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_sanitize_drops_indivisible():
    mesh = jax.make_mesh((1, 2), ("data", "model")) if len(jax.devices()) >= 2 \
        else _mesh()
    msz = mesh.shape["model"]
    dims = sanitize(mesh, ("model", None), (49155, 64))
    if msz > 1:
        assert dims == (None, None)  # 49155 % 2 != 0
    dims2 = sanitize(mesh, ("model", None), (49152, 64))
    assert dims2 == ("model", None)


def test_pspec_resolution():
    mesh = _mesh()
    assert pspec(mesh, ("batch", None, "model")) == P("data", None, "model")
    # pod axis absent from this mesh -> batch maps to data only
    assert pspec(mesh, ("seq",)) == P("data")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_under_mesh_compiles():
    mesh = _mesh()
    with use_mesh(mesh):
        @jax.jit
        def f(x):
            return constrain(x * 2, "batch", None)

        out = f(jnp.ones((8, 8)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_param_shardings_tree():
    mesh = _mesh()
    params = {
        "embed": jnp.zeros((64, 16)),
        "layers": {"attn": {"wq": jnp.zeros((2, 16, 32))}},
        "ln_f": {"scale": jnp.zeros((16,))},
    }
    sh = param_shardings(mesh, params)
    assert sh["embed"].spec == P("model", None)
    assert sh["layers"]["attn"]["wq"].spec == P(None, None, "model")
    assert sh["ln_f"]["scale"].spec == P(None)


def test_zero1_optimizer_state_shardings():
    from repro.optim.optimizers import OptConfig, state_shardings

    mesh = _mesh()
    params = {"layers": {"mlp": {"wu": jnp.zeros((16, 64, 128))}}}
    sh = state_shardings(OptConfig(name="adamw"), mesh, params)
    # m/v inherit TP spec + leading (divisible) dim sharded over data
    spec = sh["m"]["layers"]["mlp"]["wu"].spec
    assert spec == P("data", None, "model")
