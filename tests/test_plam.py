"""PLAM multiplier tests: paper eqs. (14)-(24), Fig. 4 path, error bound."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.numerics import (
    P8,
    P16,
    PositSpec,
    decode,
    exact_mul,
    encode,
    mitchell_mul_f32,
    plam_mul,
    plam_mul_logfix,
    plam_product_f32,
    plam_relative_error,
)
from repro.numerics import golden


def _all_pairs_n8():
    pa, pb = np.meshgrid(np.arange(256), np.arange(256))
    return pa.ravel().astype(np.int32), pb.ravel().astype(np.int32)


def test_plam_exhaustive_n8_vs_golden():
    s = P8
    pa, pb = _all_pairs_n8()
    gold = np.array([golden.plam_mul_py(int(a), int(b), 8, 0) for a, b in zip(pa, pb)])
    mine = np.asarray(plam_mul(jnp.asarray(pa), jnp.asarray(pb), s)) & 0xFF
    assert np.array_equal(gold, mine)


def test_exact_mul_exhaustive_n8_vs_golden():
    s = P8
    pa, pb = _all_pairs_n8()
    gold = np.array([golden.exact_mul_py(int(a), int(b), 8, 0) for a, b in zip(pa, pb)])
    mine = np.asarray(exact_mul(jnp.asarray(pa), jnp.asarray(pb), s)) & 0xFF
    assert np.array_equal(gold, mine)


def test_fig4_logfix_path_equals_field_equations():
    """The Fig. 4 hardware datapath (concat + one add) == eqs. (14)-(21)."""
    for spec in [P8, P16, PositSpec(16, 2), PositSpec(12, 1)]:
        rng = np.random.default_rng(7)
        pa = rng.integers(0, 1 << spec.n, 20000).astype(np.int32)
        pb = rng.integers(0, 1 << spec.n, 20000).astype(np.int32)
        a = np.asarray(plam_mul(jnp.asarray(pa), jnp.asarray(pb), spec))
        b = np.asarray(plam_mul_logfix(jnp.asarray(pa), jnp.asarray(pb), spec))
        assert np.array_equal(a, b)


def test_plam_sampled_n16_vs_golden():
    s = P16
    rng = np.random.default_rng(8)
    pa = rng.integers(0, 1 << 16, 10000).astype(np.int32)
    pb = rng.integers(0, 1 << 16, 10000).astype(np.int32)
    gold = np.array([golden.plam_mul_py(int(a), int(b), 16, 1) for a, b in zip(pa, pb)])
    mine = np.asarray(plam_mul(jnp.asarray(pa), jnp.asarray(pb), s)) & 0xFFFF
    assert np.array_equal(gold, mine)


def test_exact_mul_sampled_n16_vs_golden():
    s = P16
    rng = np.random.default_rng(9)
    pa = rng.integers(0, 1 << 16, 10000).astype(np.int32)
    pb = rng.integers(0, 1 << 16, 10000).astype(np.int32)
    gold = np.array([golden.exact_mul_py(int(a), int(b), 16, 1) for a, b in zip(pa, pb)])
    mine = np.asarray(exact_mul(jnp.asarray(pa), jnp.asarray(pb), s)) & 0xFFFF
    assert np.array_equal(gold, mine)


def test_error_bound_11_1_percent():
    """Paper Sec. III-C: max relative PLAM error is 1/9 ~= 11.1%."""
    s = P16
    rng = np.random.default_rng(10)
    pa = rng.integers(0, 1 << 16, 100000).astype(np.int32)
    pb = rng.integers(0, 1 << 16, 100000).astype(np.int32)
    err = np.asarray(plam_relative_error(jnp.asarray(pa), jnp.asarray(pb), s))
    assert err.max() <= 1.0 / 9.0 + 1e-6
    assert err.min() >= 0.0  # PLAM always underestimates (C_exact >= C_PLAM)
    # the bound is achieved when both fractions are 0.5 (paper, Mitchell):
    half = int(encode(jnp.float32(1.5), s))  # 1.5 = 1 + f with f = 0.5
    e = float(plam_relative_error(jnp.int32(half), jnp.int32(half), s))
    assert abs(e - 1.0 / 9.0) < 1e-6


def test_empirical_error_matches_eq24():
    """Measured (exact - plam)/exact equals the analytic formula."""
    s = P16
    rng = np.random.default_rng(11)
    # positive, mid-range posits so decode is exact and no saturation
    xs = np.float32(np.exp(rng.uniform(-3, 3, 5000)))
    ys = np.float32(np.exp(rng.uniform(-3, 3, 5000)))
    pa, pb = encode(jnp.asarray(xs), s), encode(jnp.asarray(ys), s)
    va = np.asarray(decode(pa, s), dtype=np.float64)
    vb = np.asarray(decode(pb, s), dtype=np.float64)
    exact = va * vb
    plam_lin = np.asarray(plam_product_f32(pa, pb, s), dtype=np.float64)
    emp = (exact - plam_lin) / exact
    ana = np.asarray(plam_relative_error(pa, pb, s), dtype=np.float64)
    assert np.allclose(emp, ana, atol=1e-6)


def test_plam_product_f32_matches_reencoded_value():
    """Linear PLAM product re-encoded == plam_mul pattern (mid-range)."""
    s = P16
    rng = np.random.default_rng(12)
    xs = np.float32(rng.standard_normal(5000))
    ys = np.float32(rng.standard_normal(5000))
    pa, pb = encode(jnp.asarray(xs), s), encode(jnp.asarray(ys), s)
    lin = plam_product_f32(pa, pb, s)
    re = np.asarray(encode(lin, s)) & 0xFFFF
    direct = np.asarray(plam_mul(pa, pb, s)) & 0xFFFF
    assert np.array_equal(re, direct)


def test_special_cases():
    s = P16
    nar = jnp.int32(0x8000)
    zero = jnp.int32(0)
    one = jnp.int32(0x4000)
    assert int(plam_mul(zero, one, s)) == 0
    assert int(plam_mul(one, zero, s)) == 0
    assert int(plam_mul(nar, one, s)) & 0xFFFF == 0x8000
    assert int(exact_mul(nar, zero, s)) & 0xFFFF == 0x8000
    # sign handling: (-1) * (-1) = 1, (-1) * 1 = -1
    neg_one = jnp.int32(0xC000)
    assert int(plam_mul(neg_one, neg_one, s)) == 0x4000
    assert int(plam_mul(neg_one, one, s)) & 0xFFFF == 0xC000


def test_powers_of_two_are_exact():
    """fa = fb = 0 -> PLAM error is zero (eq. 24)."""
    s = P16
    xs = jnp.asarray(np.float32([0.25, 0.5, 1.0, 2.0, 4.0, 1024.0]))
    pa = encode(xs, s)
    for i in range(6):
        for j in range(6):
            p = plam_mul(pa[i], pa[j], s)
            e = exact_mul(pa[i], pa[j], s)
            assert int(p) == int(e)


def test_mitchell_f32_reference():
    """Float-domain Mitchell: same 11.1% bound, exact on powers of two."""
    rng = np.random.default_rng(13)
    a = np.float32(np.exp(rng.uniform(-10, 10, 10000)))
    b = np.float32(np.exp(rng.uniform(-10, 10, 10000)))
    m = np.asarray(mitchell_mul_f32(jnp.asarray(a), jnp.asarray(b)), dtype=np.float64)
    exact = a.astype(np.float64) * b.astype(np.float64)
    rel = (exact - m) / exact
    assert rel.max() <= 1.0 / 9.0 + 1e-6
    assert rel.min() >= -1e-6
    assert float(mitchell_mul_f32(jnp.float32(4.0), jnp.float32(0.5))) == 2.0


@settings(max_examples=300, deadline=None)
@given(
    st.integers(min_value=0, max_value=(1 << 16) - 1),
    st.integers(min_value=0, max_value=(1 << 16) - 1),
)
def test_hypothesis_plam_matches_golden(pa, pb):
    s = P16
    mine = int(plam_mul(jnp.int32(pa), jnp.int32(pb), s)) & 0xFFFF
    gold = golden.plam_mul_py(pa, pb, 16, 1)
    assert mine == gold


@settings(max_examples=300, deadline=None)
@given(
    st.integers(min_value=0, max_value=(1 << 16) - 1),
    st.integers(min_value=0, max_value=(1 << 16) - 1),
)
def test_hypothesis_plam_commutative(pa, pb):
    s = P16
    ab = int(plam_mul(jnp.int32(pa), jnp.int32(pb), s))
    ba = int(plam_mul(jnp.int32(pb), jnp.int32(pa), s))
    assert ab == ba


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=(1 << 15) - 1))
def test_hypothesis_mul_by_one_identity(pa):
    """x * 1 == x exactly, for PLAM too (f_one = 0)."""
    s = P16
    one = 0x4000
    assert int(plam_mul(jnp.int32(pa), jnp.int32(one), s)) & 0xFFFF == pa
    assert int(exact_mul(jnp.int32(pa), jnp.int32(one), s)) & 0xFFFF == pa
