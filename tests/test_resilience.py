"""Large-scale-runnability substrate: straggler mitigation, elastic
data-axis resize, decode-attention kernel."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.straggler import StepTimer, StragglerPolicy, run_with_straggler_sim


def test_straggler_detection_and_escalation():
    flags, events = run_with_straggler_sim(
        lambda i: None,
        60,
        slow_steps={k: 0.5 for k in range(30, 36)},  # 6 consecutive slow steps
        timer=StepTimer(min_samples=5),
        policy=StragglerPolicy(patience=3, action="drop"),
        base_step_seconds=0.01,  # hermetic: no wall-clock jitter
    )
    assert all(flags[30:36]), flags[28:38]
    assert not any(flags[:30])
    assert events and events[0]["action"] == "drop"
    assert 32 <= events[0]["step"] <= 35


def test_straggler_isolated_blips_do_not_escalate():
    flags, events = run_with_straggler_sim(
        lambda i: None,
        60,
        slow_steps={20: 0.5, 40: 0.5},  # isolated blips
        timer=StepTimer(min_samples=5),
        policy=StragglerPolicy(patience=3),
        base_step_seconds=0.01,  # hermetic: no wall-clock jitter
    )
    assert flags[20] and flags[40]
    assert events == []  # never 3 in a row


def test_straggler_window_not_poisoned():
    """Flagged samples must not widen the baseline distribution."""
    t = StepTimer(min_samples=5, window=20)
    for _ in range(10):
        t.observe(0.010)
    assert t.observe(0.5)  # straggler
    assert t.observe(0.5)  # still flagged (median unchanged)


def test_elastic_data_axis_resize(tmp_path):
    """Checkpoint under batch=8 run, resume under batch=4 (half the
    'hosts'): the stateless pipeline + shape-checked restore make the
    model state carry over exactly."""
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.models import build
    from repro.optim.optimizers import OptConfig, init_state
    from repro.train import checkpoint as ckpt
    from repro.train.loop import TrainConfig, make_train_step

    cfg = ModelConfig(name="el", family="dense", n_layers=2, d_model=64, n_heads=4,
                      n_kv=2, head_dim=16, d_ff=128, vocab=64,
                      numerics=NumericsConfig(mode="f32"))
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
    step = jax.jit(make_train_step(api.train_loss, tcfg))
    state = init_state(tcfg.opt, params)
    d8 = DataConfig(seed=0, vocab=64, seq_len=32, global_batch=8)
    for i in range(5):
        params, state, _ = step(params, state, lm_batch(d8, i))
    ckpt.save(str(tmp_path), 5, (params, state))

    # "cluster shrank": restore and continue with global_batch 4
    (params2, state2), _ = ckpt.restore(str(tmp_path), (params, state))
    d4 = DataConfig(seed=0, vocab=64, seq_len=32, global_batch=4)
    params2, state2, m = step(params2, state2, lm_batch(d4, 5))
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("shape", [(2, 64, 8, 4, 16, 16), (1, 96, 4, 2, 32, 32)])
def test_decode_attention_kernel_vs_oracle(shape):
    from repro.kernels.decode_attention import decode_attention, decode_attention_ref

    b, s, h, kvh, hd, blk = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, s + 1, b).astype(np.int32))
    ref = np.asarray(decode_attention_ref(q, k, v, lens))
    out = np.asarray(decode_attention(q, k, v, lens, blk=blk, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_decode_attention_kernel_respects_lengths():
    from repro.kernels.decode_attention import decode_attention, decode_attention_ref

    rng = np.random.default_rng(1)
    b, s, h, kvh, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    lens = jnp.asarray(np.array([5, 64], np.int32))
    out = np.asarray(decode_attention(q, k, v, lens, blk=16, interpret=True))
    # batch 0 must ignore keys >= 5: recompute with truncated cache
    ref0 = np.asarray(decode_attention_ref(q[:1], k[:1, :5], v[:1, :5], jnp.asarray([5], jnp.int32)))
    np.testing.assert_allclose(out[0], ref0[0], rtol=2e-5, atol=2e-5)
