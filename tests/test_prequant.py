"""Prequantized posit weight storage: quantize_params -> nmatmul
pattern path -> serving engines -> checkpoint round trip."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig, nmatmul
from repro.core.prequant import dequantize_params, param_role, quantize_params
from repro.models import build
from repro.numerics import PositSpec, encode, pack16, quantize

DENSE = dict(family="dense", n_layers=2, d_model=32, n_heads=2, n_kv=2,
             head_dim=16, d_ff=64, vocab=50)
MOE = dict(family="moe", n_layers=2, d_model=32, n_heads=2, n_kv=2,
           head_dim=16, d_ff=64, vocab=50, n_experts=4, top_k=2,
           moe_d_ff=32, n_shared_experts=1)


def test_param_role_mapping():
    assert param_role("layers/attn/wq") == "attn.qkv"
    assert param_role("layers/attn/wo") == "attn.out"
    assert param_role("layers/mlp/wg") == "mlp.gate"
    assert param_role("layers/moe/router") == "moe.router"
    assert param_role("layers/moe/wd") == "moe.expert.down"
    assert param_role("layers/moe/shared/wu") == "moe.shared.up"
    assert param_role("layers/mamba/in_proj") == "ssm.proj.in"
    assert param_role("dec_layers/xattn/wq") == "attn.cross.qkv"
    assert param_role("unembed") == "lm_head"
    assert param_role("embed") is None
    assert param_role("layers/ln1/scale") is None
    assert param_role("layers/mamba/conv_w") is None


def test_pattern_nmatmul_matches_linear_paths():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    spec = PositSpec(16, 1)
    bits = pack16(encode(w, spec))
    # posit_quant: decoded patterns are exactly quantize(w) -> bit-equal
    pq = NumericsConfig(mode="posit_quant", n=16, es=1)
    assert np.array_equal(np.asarray(nmatmul(x, w, pq)),
                          np.asarray(nmatmul(x, bits, pq)))
    # plam_sim: kernel tiling reorders the f32 accumulation -> allclose
    pl = NumericsConfig(mode="plam_sim", n=16, es=1)
    a, b = np.asarray(nmatmul(x, w, pl)), np.asarray(nmatmul(x, bits, pl))
    assert np.allclose(a, b, rtol=1e-5, atol=1e-5)


def test_quantize_params_selects_posit_sites_only():
    cfg = ModelConfig(**MOE).with_numerics(
        "default=plam_sim:16:1, attn=posit_quant:16:1, lm_head=f32")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pq, meta = quantize_params(cfg, params)
    assert pq["layers"]["attn"]["wq"].dtype == jnp.int16
    assert pq["layers"]["moe"]["wu"].dtype == jnp.int16
    # f32 sites and non-matmul params stay linear
    assert pq["layers"]["moe"]["router"].dtype == jnp.float32
    assert pq["unembed"].dtype == jnp.float32
    assert pq["embed"].dtype == jnp.float32
    assert meta["layers/attn/wq"] == {
        "role": "attn.qkv", "mode": "posit_quant", "n": 16, "es": 1}
    # dequantize recovers the posit-grid values
    deq = dequantize_params(pq, meta)
    grid = quantize(params["layers"]["attn"]["wq"], PositSpec(16, 1))
    assert np.array_equal(np.asarray(deq["layers"]["attn"]["wq"]),
                          np.asarray(grid))


def test_layer_mixed_site_not_prequantized():
    """A site whose spec differs across layers cannot share one packed
    array: it must stay linear."""
    cfg = ModelConfig(**DENSE).with_numerics(
        "default=plam_sim:16:1, mlp@layers[0]=plam_sim:8:0")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pq, meta = quantize_params(cfg, params)
    assert pq["layers"]["mlp"]["wu"].dtype == jnp.float32
    assert "layers/mlp/wu" not in meta
    # attn is layer-uniform -> still quantized
    assert pq["layers"]["attn"]["wq"].dtype == jnp.int16


def test_engine_prequantize_token_identical_posit_quant():
    """posit_quant decode-of-patterns == quantize-on-read, so greedy
    generation is token-identical with and without prequantization."""
    from repro.serving.engine import Engine, ServeConfig

    cfg = ModelConfig(**DENSE, numerics=NumericsConfig(mode="posit_quant"))
    prompts = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 50, (2, 8)).astype(np.int32))}
    scfg = ServeConfig(max_new_tokens=4)
    a = Engine(cfg, key=jax.random.PRNGKey(0)).generate(prompts, scfg)
    b = Engine(cfg, key=jax.random.PRNGKey(0), prequantize=True).generate(
        prompts, scfg)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_paged_engine_serves_prequantized_plam():
    from repro.serving.engine import ContinuousBatchingEngine, PagedServeConfig

    cfg = ModelConfig(**MOE).with_numerics("default=plam_sim:16:1, lm_head=f32")
    eng = ContinuousBatchingEngine(
        cfg, key=jax.random.PRNGKey(0),
        pcfg=PagedServeConfig(block_size=8, num_blocks=32, max_slots=2,
                              max_seq_len=32, prequantize=True))
    assert eng.params["layers"]["moe"]["wu"].dtype == jnp.int16
    assert eng.prequant_meta
    r = eng.submit(list(range(1, 9)), max_new_tokens=4)
    done = eng.run()
    assert len(done[r.rid]) == 4


def test_prequantized_checkpoint_round_trip(tmp_path):
    from repro.train import checkpoint as ckpt

    cfg = ModelConfig(**DENSE).with_numerics("default=plam_sim:16:1")
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pq, meta = quantize_params(cfg, params)
    extra = dict(ckpt.policy_extra(cfg.numerics), prequant=meta)
    ckpt.save(str(tmp_path), 0, pq, extra=extra)
    restored, manifest = ckpt.restore(str(tmp_path), pq)
    assert manifest["extra"]["prequant"] == meta
    same = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b))
        and a.dtype == b.dtype,
        pq, restored)
    assert all(jax.tree.leaves(same))
    # restored patterns still serve
    logits, _ = api.prefill(restored, {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert np.isfinite(np.asarray(logits)).all()
