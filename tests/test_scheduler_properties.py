"""Property tests: scheduler / allocator invariants under random load.

Shim-compatible (tests/_hypothesis_shim.py): drives randomized request
streams — staggered arrivals, random prompt/output lengths, random
early finishes, speculative bursts with random acceptance, and (under
``preemption="recompute"``) forced pool pressure with random
priorities, deadlines and mid-stream client cancels — through the REAL
Scheduler + BlockAllocator (no model, no device work) and asserts the
structural invariants every engine build relies on:

* no block is owned by two live sequences (no double allocation);
* block 0 (scratch) is never handed out;
* free-list cardinality + owned blocks == pool size at every step, and
  the free list is fully restored once every request finishes or is
  cancelled (no leaks);
* ``verified_len <= drafted_len <= reserved capacity`` at every step —
  the speculative write burst can never escape a sequence's own blocks;
* a preempted request holds ZERO blocks and no slot while parked;
* the committed length (prompt + generated output) is monotone per
  request across preempt/resume cycles — eviction resets the cache
  bookkeeping, never the stream;
* every admitted request eventually finishes or is deadline-cancelled
  (the deservingness total order rules out livelock).

``REPRO_PROP_MULT`` multiplies every ``max_examples`` (the CI stress
job runs at 10x) and ``REPRO_PROP_SEED`` offsets the derived rng
streams so a seed matrix explores disjoint example sets.
"""
import os

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serving import (
    BlockAllocator,
    Request,
    RequestState,
    Scheduler,
    SequenceAllocation,
    SCRATCH_BLOCK,
    padded_prompt_len,
)

_MULT = int(os.environ.get("REPRO_PROP_MULT", "1"))
_SEED = int(os.environ.get("REPRO_PROP_SEED", "0"))


def _check_invariants(sched: Scheduler, al: BlockAllocator) -> None:
    owned = [b for r in sched.running.values() for b in r.alloc.blocks]
    pins = [r.cow_src for r in sched.running.values() if r.cow_src is not None]
    if not al.prefix_cache:
        # without content addressing a block has exactly one owner; with
        # it, shared prefix blocks legitimately appear in many tables
        assert len(owned) == len(set(owned)), "block double-allocated"
        assert al.num_free + len(owned) == al.num_blocks - 1, "block leak"
    assert SCRATCH_BLOCK not in owned, "scratch block handed out"
    # refcount conservation, both ways: every block is free, parked as
    # idle cache, or referenced — and every reference is exactly one
    # sequence's table entry or one COW pin
    assert (al.num_free + al.num_cached_idle + al.num_referenced
            == al.num_blocks - 1), "refcount conservation violated"
    assert (sum(al.refcount(b) for b in range(al.num_blocks))
            == len(owned) + len(pins)), "dangling/missing reference"
    for r in sched.running.values():
        assert r.verified_len <= r.drafted_len <= r.alloc.capacity(), (
            r.rid, r.verified_len, r.drafted_len, r.alloc.capacity())
    for r in sched.preempted:
        assert r.alloc is None and r.slot == -1, (
            "preempted request still holds blocks/slot", r.rid)
        assert r.cow_src is None, ("preempted request holds a COW pin", r.rid)


@settings(max_examples=25 * _MULT, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=5),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=4),
)
def test_random_stream_preserves_invariants(seed, block_size, max_slots, spec_k):
    rng = np.random.default_rng(seed + 1 + _SEED * 100_003)
    num_blocks = int(rng.integers(6, 40))
    max_seq_len = int(rng.integers(8, 64))
    al = BlockAllocator(num_blocks, block_size)
    sched = Scheduler(al, max_slots, max_seq_len, spec_k=spec_k)

    arrival = 0
    for rid in range(int(rng.integers(1, 12))):
        plen = int(rng.integers(1, max_seq_len))
        max_new = int(rng.integers(1, max_seq_len - plen + 1))
        req = Request(rid=rid, prompt=[0] * plen, max_new_tokens=max_new,
                      arrival_step=arrival)
        arrival += int(rng.integers(0, 3))
        try:
            sched.submit(req)
        except ValueError:
            continue  # could never fit the pool: rejected at submit

    step = 0
    while sched.has_work():
        for req in sched.admit(step):
            # simulate prefill: the whole (block-padded) prompt written
            req.verified_len = req.prompt_len
            req.drafted_len = padded_prompt_len(req.prompt_len, block_size)
            req.output.append(0)
            _check_invariants(sched, al)
        for req in list(sched.running.values()):
            if req.output and rng.random() < 0.15:
                sched.retire(req, step)  # random early finish (stop token)
                _check_invariants(sched, al)
                continue
            remaining = req.max_new_tokens - len(req.output)
            if remaining <= 0:
                sched.retire(req, step)
                _check_invariants(sched, al)
                continue
            if spec_k and remaining > 0:
                # speculative burst: k+1 positions written, then the
                # logical length rolled back to a random commit point
                base = req.verified_len
                req.drafted_len = max(req.drafted_len, base + spec_k + 1)
                commit = min(int(rng.integers(1, spec_k + 2)), remaining)
                sched.rollback(req, base + commit)
                req.output.extend([0] * commit)
            else:
                req.verified_len += 1
                req.drafted_len = max(req.drafted_len, req.verified_len)
                req.output.append(0)
            _check_invariants(sched, al)
        step += 1
        assert step < 10_000, "stream did not drain"

    assert al.num_free == al.num_blocks - 1, "free list not restored"
    assert not sched.running and not sched.waiting


@settings(max_examples=50 * _MULT, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=47),
    st.integers(min_value=0, max_value=48),
)
def test_blocks_covering_matches_bruteforce(n_blocks, block_size, start, stop):
    """blocks_covering([start, stop)) is exactly the set of blocks a
    position-by-position walk touches."""
    alloc = SequenceAllocation(list(range(1, n_blocks + 1)), block_size)
    cap = alloc.capacity()
    start = min(start, cap)
    stop = min(stop, cap)
    got = alloc.blocks_covering(start, stop)
    brute = []
    for pos in range(start, stop):
        b = alloc.blocks[pos // block_size]
        if b not in brute:
            brute.append(b)
    assert got == brute, (start, stop, block_size, got, brute)


@settings(max_examples=25 * _MULT, deadline=None)
@given(st.integers(min_value=0, max_value=10_000), st.integers(min_value=1, max_value=4))
def test_retire_reports_exactly_the_stale_blocks(seed, spec_k):
    """What retire() hands back for scrubbing is precisely the blocks
    covering [verified_len, drafted_len) — no more (committed-only
    blocks are reusable as-is under the length masks), no fewer (every
    block holding never-committed K/V is scrubbed)."""
    rng = np.random.default_rng(seed + _SEED * 100_003)
    bs = int(rng.integers(2, 6))
    al = BlockAllocator(64, bs)
    sched = Scheduler(al, 2, 64, spec_k=spec_k)
    plen = int(rng.integers(1, 20))
    max_new = int(rng.integers(2, 20))
    req = Request(rid=0, prompt=[0] * plen, max_new_tokens=max_new)
    sched.submit(req)
    sched.admit(step=0)
    req.verified_len = plen
    req.drafted_len = padded_prompt_len(plen, bs)
    burst = int(rng.integers(0, spec_k + 2))
    req.drafted_len = max(req.drafted_len, req.verified_len + burst)
    assert req.drafted_len <= req.alloc.capacity()
    expect = req.alloc.blocks_covering(req.verified_len, req.drafted_len)
    assert sched.retire(req, step=1) == expect
    assert al.num_free == al.num_blocks - 1


# ---------------------------------------------------------------------------
# preemptive scheduling (preemption="recompute")
# ---------------------------------------------------------------------------

def _sim_prefill(req: Request, block_size: int) -> None:
    """What the engine does when a request is (re)admitted: write the
    whole block-padded prefill context in one shot.  Fresh requests
    sample their first token from the prefill logits; a resumed request
    already committed that token (it is re-fed to decode instead)."""
    req.prefill_pos = req.prefill_len
    req.verified_len = req.prefill_len
    req.drafted_len = padded_prompt_len(req.prefill_len, block_size)
    if not req.output:
        req.output.append(0)


@settings(max_examples=25 * _MULT, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=5),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=3),
)
def test_preemptive_stream_preserves_invariants(seed, block_size, max_slots,
                                                spec_k):
    """Random streams under FORCED pool pressure (pools sized so
    concurrent requests must collide), random priorities, wall-clock
    deadlines on a fake clock, and random mid-stream client cancels —
    driven through the real preemptive scheduler.  On top of the base
    invariants (checked after every mutation): parked requests hold
    zero blocks, committed length is monotone across preempt/resume,
    and every submitted request eventually finishes or is cancelled."""
    rng = np.random.default_rng(seed + 1 + _SEED * 100_003)
    num_blocks = int(rng.integers(4, 14))
    max_seq_len = int(rng.integers(8, 40))
    clock = [0.0]
    al = BlockAllocator(num_blocks, block_size)
    sched = Scheduler(al, max_slots, max_seq_len, spec_k=spec_k,
                      preemption="recompute", clock=lambda: clock[0])

    reqs = []
    arrival = 0
    for rid in range(int(rng.integers(2, 14))):
        plen = int(rng.integers(1, max_seq_len))
        max_new = int(rng.integers(1, max_seq_len - plen + 1))
        req = Request(
            rid=rid, prompt=[rid % 7] * plen, max_new_tokens=max_new,
            arrival_step=arrival,
            priority=int(rng.integers(0, 3)),
            deadline_s=(float(rng.integers(1, 40))
                        if rng.random() < 0.3 else None),
            submit_time=clock[0])
        arrival += int(rng.integers(0, 3))
        try:
            sched.submit(req)
        except ValueError:
            continue  # could never fit the pool: rejected at submit
        reqs.append(req)

    committed_hwm = {r.rid: r.committed_len for r in reqs}

    def check():
        _check_invariants(sched, al)
        for r in reqs:
            assert r.committed_len >= committed_hwm[r.rid], (
                "committed stream shrank across preempt/resume", r.rid)
            committed_hwm[r.rid] = r.committed_len

    w = spec_k + 1 if spec_k else 1
    step = 0
    while sched.has_work():
        clock[0] += float(rng.random())
        for req in sched.expired(clock[0]):
            sched.cancel(req, step)
            check()
        for req in sched.admit(step, on_preempt=None):
            _sim_prefill(req, block_size)
            check()
        # growth + decode, most deserving first (the engine's order —
        # victims under pressure are exactly the least deserving)
        for req in sorted(sched.running.values(), key=Scheduler.deserving,
                          reverse=True):
            if req.state is not RequestState.RUNNING:
                continue  # evicted by a more deserving grower this step
            if rng.random() < 0.04:
                sched.cancel(req, step)  # client abort mid-stream
                check()
                continue
            if req.is_done() or (req.output and rng.random() < 0.10):
                sched.retire(req, step)  # natural or stop-token finish
                check()
                continue
            if not sched.grow(req, req.verified_len + w, None, step):
                check()  # self-preempted: parked holding nothing
                continue
            if spec_k:
                base = req.verified_len
                req.drafted_len = max(req.drafted_len, base + w)
                commit = min(int(rng.integers(1, w + 1)),
                             req.max_new_tokens - len(req.output))
                sched.rollback(req, base + commit)
                req.output.extend([0] * commit)
            else:
                req.verified_len += 1
                req.drafted_len = max(req.drafted_len, req.verified_len)
                req.output.append(0)
            check()
        step += 1
        assert step < 20_000, "stream did not drain (livelock?)"

    for r in reqs:
        assert r.state in (RequestState.FINISHED, RequestState.CANCELLED), (
            "request neither finished nor cancelled", r.rid, r.state)
        assert r.alloc is None and r.slot == -1
    assert al.num_free == al.num_blocks - 1, "free list not restored"
    assert not sched.running and not sched.waiting and not sched.preempted


# ---------------------------------------------------------------------------
# prefix caching (content-addressed allocator, refcounted sharing)
# ---------------------------------------------------------------------------

def _sim_prefill_cached(req: Request, al: BlockAllocator,
                        block_size: int) -> None:
    """What the engine does when a cache-aware activation reaches its
    (suffix) prefill: apply the pending copy-on-write (releasing the
    pinned source), write [prefill_pos, prefill_len) plus block
    padding, then register the full-block prefix — content first,
    mapping second."""
    if req.cow_src is not None:
        al.release([req.cow_src])
        req.cow_src = None
    start = req.prefill_pos
    req.prefill_pos = req.prefill_len
    req.verified_len = req.prefill_len
    width = padded_prompt_len(req.prefill_len - start, block_size)
    req.drafted_len = max(req.drafted_len,
                          min(start + width, req.alloc.capacity()))
    al.register(req.prefill_tokens, req.alloc.blocks)
    al.drain_evicted()  # the engine scrubs these before the next write
    if not req.output:
        req.output.append(0)


@settings(max_examples=25 * _MULT, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=5),
    st.integers(min_value=1, max_value=4),
    st.integers(min_value=0, max_value=3),
    st.sampled_from([False, True]),
)
def test_prefix_cache_stream_preserves_invariants(seed, block_size, max_slots,
                                                  spec_k, preemptive):
    """Random streams of SHARED-PREFIX prompts (three prefix families,
    random split points) through a prefix-caching allocator on a pool
    small enough that retired prefixes park on the LRU and later
    admissions evict them — under both FCFS and preemptive scheduling,
    with random cancels.  On top of the base invariants, refcount
    conservation (free + cached-idle + referenced == pool) and the
    reference census (sum of refcounts == table entries + COW pins) are
    checked after every mutation; a preempted request holds no
    refcount, which the census implies and the COW-pin check pins."""
    rng = np.random.default_rng(seed + 1 + _SEED * 100_003)
    num_blocks = int(rng.integers(6, 20))
    max_seq_len = int(rng.integers(8, 40))
    clock = [0.0]
    al = BlockAllocator(num_blocks, block_size, prefix_cache=True)
    sched = Scheduler(al, max_slots, max_seq_len, spec_k=spec_k,
                      preemption="recompute" if preemptive else "off",
                      clock=lambda: clock[0])

    reqs = []
    arrival = 0
    for rid in range(int(rng.integers(2, 14))):
        fam = int(rng.integers(0, 3))
        pref_len = int(rng.integers(0, max_seq_len - 1))
        plen = pref_len + int(rng.integers(1, max_seq_len - pref_len))
        prompt = ([(fam * 29 + j) % 97 for j in range(pref_len)]
                  + [1000 + rid * 50 + j for j in range(plen - pref_len)])
        max_new = int(rng.integers(1, max_seq_len - plen + 1))
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                      arrival_step=arrival,
                      priority=int(rng.integers(0, 3)),
                      submit_time=clock[0])
        arrival += int(rng.integers(0, 3))
        try:
            sched.submit(req)
        except ValueError:
            continue  # could never fit the pool: rejected at submit
        reqs.append(req)

    w = spec_k + 1 if spec_k else 1
    step = 0
    while sched.has_work():
        clock[0] += float(rng.random())
        for req in sched.admit(step, on_preempt=None):
            assert req.prefill_pos == req.cached_len <= req.prefill_len - 1
            _check_invariants(sched, al)
            _sim_prefill_cached(req, al, block_size)
            _check_invariants(sched, al)
        for req in sorted(sched.running.values(), key=Scheduler.deserving,
                          reverse=True):
            if req.state is not RequestState.RUNNING:
                continue  # evicted by a more deserving grower this step
            if not req.prefill_done:
                continue
            if rng.random() < 0.04:
                sched.cancel(req, step)  # client abort mid-stream
                _check_invariants(sched, al)
                continue
            if req.is_done() or (req.output and rng.random() < 0.10):
                sched.retire(req, step)
                _check_invariants(sched, al)
                continue
            if preemptive:
                if not sched.grow(req, req.verified_len + w, None, step):
                    _check_invariants(sched, al)
                    continue
            if spec_k:
                base = req.verified_len
                req.drafted_len = max(req.drafted_len, base + w)
                commit = min(int(rng.integers(1, w + 1)),
                             req.max_new_tokens - len(req.output))
                sched.rollback(req, base + commit)
                req.output.extend([0] * commit)
            else:
                req.verified_len += 1
                req.drafted_len = max(req.drafted_len, req.verified_len)
                req.output.append(0)
            _check_invariants(sched, al)
        step += 1
        assert step < 20_000, "stream did not drain (livelock?)"

    for r in reqs:
        assert r.state in (RequestState.FINISHED, RequestState.CANCELLED)
        assert r.alloc is None and r.slot == -1 and r.cow_src is None
    # drained pool: no references survive; registered prefixes park on
    # the LRU (still-valid cache), everything else is back on the free
    # list, and together they exhaust the allocatable pool
    assert al.num_referenced == 0, "a retired request left a refcount"
    assert al.num_free + al.num_cached_idle == al.num_blocks - 1
    assert not sched.running and not sched.waiting and not sched.preempted
