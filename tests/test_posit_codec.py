"""Codec tests: JAX bit codec vs pure-Python golden vs exhaustive tables."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.numerics import (
    P16,
    PositSpec,
    decode,
    decode_table,
    encode,
    encode_table,
    pack16,
    quantize,
    unpack16,
)
from repro.numerics import golden

SPECS = [PositSpec(8, 0), PositSpec(8, 1), PositSpec(16, 1), PositSpec(16, 2), PositSpec(12, 1)]


def _match(a, b):
    return (a == b) | (np.isnan(a) & np.isnan(b))


@pytest.mark.parametrize("spec", SPECS, ids=str)
def test_decode_exhaustive_vs_golden(spec):
    n = spec.n
    if n > 12:  # keep runtime bounded; 16-bit covered by sampling below
        pats = np.random.default_rng(0).integers(0, 1 << n, 4096).astype(np.int32)
    else:
        pats = np.arange(1 << n, dtype=np.int32)
    gold = np.array([golden.decode_py(int(p), n, spec.es) for p in pats])
    mine = np.asarray(decode(jnp.asarray(pats), spec), dtype=np.float64)
    assert _match(gold, mine).all()


@pytest.mark.parametrize("spec", SPECS, ids=str)
def test_roundtrip_identity(spec):
    """encode(decode(p)) == p for every pattern: codec is a bijection."""
    n = spec.n
    pats = np.arange(1 << n, dtype=np.int32) if n <= 12 else \
        np.random.default_rng(1).integers(0, 1 << n, 8192).astype(np.int32)
    rt = np.asarray(encode(decode(jnp.asarray(pats), spec), spec)) & spec.mask_n
    assert np.array_equal(rt, pats & spec.mask_n)


@pytest.mark.parametrize("spec", [PositSpec(16, 1), PositSpec(8, 0)], ids=str)
def test_encode_random_floats_vs_golden(spec):
    rng = np.random.default_rng(2)
    xs = np.float32(rng.standard_normal(4000) * np.exp(rng.uniform(-30, 30, 4000)))
    xs = np.concatenate([xs, [0.0, np.inf, -np.inf, np.nan, 1.0, -1.0]]).astype(np.float32)
    gold = np.array([golden.encode_py(float(v), spec.n, spec.es) for v in xs], dtype=np.int64)
    mine = np.asarray(encode(jnp.asarray(xs), spec)).astype(np.int64) & spec.mask_n
    assert np.array_equal(gold, mine)


@pytest.mark.parametrize("spec", [PositSpec(16, 1), PositSpec(8, 0)], ids=str)
def test_rne_tie_to_even_pattern(spec):
    """Values exactly on the rounding threshold go to the even pattern."""
    ths = np.array(golden.thresholds(spec.n, spec.es)[:3000], dtype=np.float32)
    mine = np.asarray(encode(jnp.asarray(ths), spec)).astype(np.int64) & spec.mask_n
    gold = np.array([golden.encode_py(float(v), spec.n, spec.es) for v in ths], dtype=np.int64)
    assert np.array_equal(gold, mine)
    assert (mine % 2 == 0).all()  # even patterns by construction


@pytest.mark.parametrize("spec", [PositSpec(16, 1), PositSpec(8, 0), PositSpec(16, 2)], ids=str)
def test_table_codec_agrees_with_bit_codec(spec):
    rng = np.random.default_rng(3)
    xs = np.float32(rng.standard_normal(4000) * np.exp(rng.uniform(-30, 30, 4000)))
    et = np.asarray(encode_table(jnp.asarray(xs), spec)) & spec.mask_n
    em = np.asarray(encode(jnp.asarray(xs), spec)) & spec.mask_n
    assert np.array_equal(et, em)
    pats = rng.integers(0, 1 << spec.n, 4000).astype(np.int32)
    dt = np.asarray(decode_table(jnp.asarray(pats), spec))
    dm = np.asarray(decode(jnp.asarray(pats), spec))
    assert _match(dt, dm).all()


def test_known_posit16_constants():
    s = P16
    assert float(decode(jnp.int32(0x4000), s)) == 1.0
    assert float(decode(jnp.int32(0xC000), s)) == -1.0
    assert float(decode(jnp.int32(0x7FFF), s)) == 2.0 ** 28  # maxpos
    assert float(decode(jnp.int32(0x0001), s)) == 2.0 ** -28  # minpos
    assert float(decode(jnp.int32(0x5000), s)) == 2.0
    assert float(decode(jnp.int32(0x3000), s)) == 0.5
    assert np.isnan(float(decode(jnp.int32(0x8000), s)))
    assert int(encode(jnp.float32(1.0), s)) == 0x4000
    assert int(encode(jnp.float32(0.0), s)) == 0


def test_saturation_no_rounding_to_zero_or_nar():
    s = P16
    assert int(encode(jnp.float32(1e30), s)) == 0x7FFF  # maxpos, not NaR
    assert int(encode(jnp.float32(1e-30), s)) == 0x0001  # minpos, not zero
    assert int(encode(jnp.float32(-1e30), s)) & 0xFFFF == 0x8001  # -maxpos


def test_quantize_idempotent_and_ste():
    import jax

    s = P16
    rng = np.random.default_rng(4)
    x = jnp.asarray(np.float32(rng.standard_normal(1000)))
    q1 = quantize(x, s)
    q2 = quantize(q1, s)
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    # straight-through gradient is identity
    g = jax.grad(lambda v: jnp.sum(quantize(v, s)))(x)
    assert np.allclose(np.asarray(g), 1.0)


def test_pack16_roundtrip():
    pats = jnp.asarray(np.random.default_rng(5).integers(0, 1 << 16, 1000).astype(np.int32))
    assert np.array_equal(np.asarray(unpack16(pack16(pats))), np.asarray(pats))


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=-999999995904.0, max_value=999999995904.0, allow_nan=False, width=32))
def test_hypothesis_encode_matches_golden(x):
    s = P16
    mine = int(encode(jnp.float32(x), s)) & 0xFFFF
    gold = golden.encode_py(float(np.float32(x)), 16, 1)
    assert mine == gold


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=9.999999960041972e-13, max_value=999999995904.0, allow_nan=False, width=32))
def test_hypothesis_quantize_monotone(x):
    """Quantization is monotone: q(x) <= q(x * 1.5)."""
    s = P16
    a = float(quantize(jnp.float32(x), s))
    b = float(quantize(jnp.float32(x * 1.5), s))
    assert a <= b
