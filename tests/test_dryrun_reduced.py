"""End-to-end dry-run pipeline test at CI scale.

Runs in a subprocess with 8 virtual XLA host devices (the flag must be
set before jax initializes, and pytest's process already has 1 device),
builds a (2 data x 4 model) mesh, and lowers+compiles a sharded train
step and decode step for reduced configs of three families.  This is
the same code path as the 512-chip production dry-run.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.launch.dryrun import build_cell
from repro.launch.hlo_analysis import analyze
from repro.parallel.sharding import use_mesh

results = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ["yi-6b", "granite-moe-1b-a400m", "mamba2-780m"]:
    cfg = get_config(arch).reduced()
    shape = ShapeSpec("ci", seq_len=64, global_batch=4, kind="train")
    with use_mesh(mesh):
        step, args, shardings = build_cell(cfg, shape, mesh)
        compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    ana = analyze(compiled.as_text())
    results[arch] = {
        "flops": ana.flops,
        "collective_total": ana.collective_total,
        "mem": compiled.memory_analysis().temp_size_in_bytes,
    }

# decode path for the dense family
cfg = get_config("yi-6b").reduced()
shape = ShapeSpec("ci-dec", seq_len=64, global_batch=4, kind="decode")
with use_mesh(mesh):
    step, args, shardings = build_cell(cfg, shape, mesh)
    compiled = jax.jit(step, in_shardings=shardings).lower(*args).compile()
results["yi-6b-decode"] = {"ok": True}
print("RESULT " + json.dumps(results))
"""


@pytest.mark.slow
def test_dryrun_pipeline_on_8_virtual_devices():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = next(l for l in out.stdout.splitlines() if l.startswith("RESULT "))
    results = json.loads(line[len("RESULT "):])
    for arch in ["yi-6b", "granite-moe-1b-a400m", "mamba2-780m"]:
        assert results[arch]["flops"] > 0, results
        # data-parallel gradient reduction must appear
        assert results[arch]["collective_total"] > 0, results
    assert results["yi-6b-decode"]["ok"]
