"""Speculative decoding (PR 4 acceptance bar).

Greedy spec decoding is an execution strategy, not a model: for every
k and every engine configuration the committed token stream must be
IDENTICAL to the plain one-token-per-step engine.  The tp=2 cases need
a multi-device platform (subprocess, forced host devices — marked
slow); everything else runs in-process on the toy config.

Also covered: acceptance-rate sanity (drafters that should be accepted
are, adversarial drafters are not; a context ending in an established
greedy cycle accepts more than a fresh random prompt), mid-burst
stop_token / max_new truncation, and the drafters themselves.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.models import build
from repro.serving import (
    ContinuousBatchingEngine,
    DraftModelDrafter,
    NgramDrafter,
    PagedServeConfig,
    make_drafter,
)
from repro.serving.scheduler import Request

CFG = ModelConfig(
    name="toy-spec", family="dense", n_layers=2, d_model=32, n_heads=4,
    n_kv=2, head_dim=8, d_ff=64, vocab=61,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def params():
    return build(CFG).init(jax.random.PRNGKey(0))


def _run(params, prompts, *, max_new=6, spec_k=0, chunk=0, drafter=None,
         stop_token=None, max_seq_len=48, num_blocks=96, max_slots=3):
    pcfg = PagedServeConfig(
        block_size=4, num_blocks=num_blocks, max_slots=max_slots,
        max_seq_len=max_seq_len, prefill_chunk=chunk, spec_k=spec_k)
    if drafter is not None:
        pcfg.spec_draft = drafter
    eng = ContinuousBatchingEngine(CFG, params=params, pcfg=pcfg)
    reqs = [eng.submit(p, max_new_tokens=max_new, arrival_step=i,
                       stop_token=stop_token)
            for i, p in enumerate(prompts)]
    done = eng.run()
    return [done[r.rid] for r in reqs], eng


# ---------------------------------------------------------------------------
# token identity: spec on == spec off, across k and chunking
# ---------------------------------------------------------------------------

def test_spec_token_identical_k_chunk_matrix(params):
    """Greedy spec decoding with k in {1, 2, 4}, chunked and unchunked,
    over mixed-length staggered prompts, commits EXACTLY the tokens the
    non-spec engine produces."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 61, n).tolist() for n in (3, 9, 17, 6)]
    base, _ = _run(params, prompts)
    for k in (1, 2, 4):
        for chunk in (0, 8):
            got, eng = _run(params, prompts, spec_k=k, chunk=chunk)
            assert got == base, f"spec_k={k} chunk={chunk} diverged"
            assert eng.stats.spec_steps > 0
            assert eng.allocator.num_free == eng.allocator.num_blocks - 1
            # a verify step can only speed decode up, never slow it down
            assert eng.stats.tokens_per_verify_step() >= 1.0


def test_spec_invariants_tracked(params):
    """verified_len / drafted_len survive retirement and respect the
    rollback invariant; the engine reports the spec stats the bench
    consumes."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 61, 7).tolist()]
    got, eng = _run(params, prompts, spec_k=4, max_new=8)
    assert len(got[0]) == 8
    assert eng.stats.drafted_tokens == 4 * eng.stats.spec_steps
    assert 0.0 <= eng.stats.acceptance_rate() <= 1.0
    assert eng.stats.spec_committed_tokens + 1 == eng.stats.generated_tokens


# ---------------------------------------------------------------------------
# acceptance-rate sanity
# ---------------------------------------------------------------------------

class _ReplayDrafter:
    """Oracle drafter: replays a known greedy continuation."""

    def __init__(self, expect):
        self.expect = expect

    def propose(self, req, k):
        n = len(req.output)
        d = list(self.expect[n:n + k])
        return (d + [0] * k)[:k]


class _AdversarialDrafter:
    """Always drafts a token greedy decode will not pick next (it
    shifts the last token by a constant off the argmax)."""

    def propose(self, req, k):
        return [(req.output[-1] + 17) % CFG.vocab] * k


@pytest.mark.slow
def test_spec_acceptance_tracks_draft_quality(params):
    """An oracle drafter is accepted nearly always (and the run still
    matches the baseline); an adversarial drafter is never accepted —
    and even then the stream stays identical, one token per verify.

    Slow lane: acceptance METRICS need long generations (24 tokens x 3
    engine builds); the token-identity gates stay in the fast lane."""
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 61, 18).tolist()
    base, _ = _run(params, [prompt], max_new=24, max_seq_len=128)
    replay, eng_r = _run(params, [prompt], max_new=24, max_seq_len=128,
                         spec_k=4, drafter=_ReplayDrafter(base[0]))
    assert replay == base
    assert eng_r.stats.acceptance_rate() > 0.8
    assert eng_r.stats.tokens_per_verify_step() > 3.0
    adv, eng_a = _run(params, [prompt], max_new=24, max_seq_len=128,
                      spec_k=4, drafter=_AdversarialDrafter())
    assert adv == base
    assert eng_a.stats.acceptance_rate() == 0.0
    assert eng_a.stats.tokens_per_verify_step() == 1.0
    assert eng_r.stats.decode_steps < eng_a.stats.decode_steps


@pytest.mark.slow
def test_ngram_acceptance_repetitive_beats_random(params):
    """Self-speculative n-gram lookup accepts more on a context whose
    greedy continuation is predictable from the context itself (an
    established repetition cycle) than on a fresh random prompt.

    Slow lane: needs a 48-token generation to establish the cycle."""
    rng = np.random.default_rng(5)
    rand = rng.integers(0, 61, 18).tolist()
    base, _ = _run(params, [rand], max_new=48, max_seq_len=160)
    rep_ctx = rand + base[0]  # greedy loop established at the tail
    a_rep = _run(params, [rep_ctx], max_new=24, max_seq_len=160,
                 spec_k=4)[1].stats.acceptance_rate()
    a_rand = _run(params, [rand], max_new=24, max_seq_len=160,
                  spec_k=4)[1].stats.acceptance_rate()
    assert a_rep > a_rand, (a_rep, a_rand)


# ---------------------------------------------------------------------------
# mid-burst truncation
# ---------------------------------------------------------------------------

def test_spec_stop_token_mid_burst(params):
    """A stop token that fires inside a verify burst truncates the
    commit exactly where the sequential engine stops, and every block
    is released."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 61, 5).tolist()
    base, _ = _run(params, [prompt], max_new=12)
    stop = base[0][6]
    expect_idx = base[0].index(stop)  # first occurrence wins
    expect = base[0][:expect_idx + 1]
    for k in (2, 4):
        got, eng = _run(params, [prompt], max_new=12, spec_k=k,
                        stop_token=stop)
        assert got[0] == expect, f"spec_k={k}"
        assert eng.allocator.num_free == eng.allocator.num_blocks - 1


def test_spec_max_new_truncates_final_burst(params):
    """max_new that is not a multiple of k+1: the final verify commits
    only the remaining quota."""
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 61, 6).tolist()
    for max_new in (2, 3, 7):
        base, _ = _run(params, [prompt], max_new=max_new)
        got, _ = _run(params, [prompt], max_new=max_new, spec_k=4)
        assert got == base and len(got[0]) == max_new


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------

def test_ngram_drafter_lookup():
    d = NgramDrafter(max_n=3)
    req = Request(rid=0, prompt=[1, 2, 3, 4, 9, 9, 1, 2, 3], max_new_tokens=4)
    # suffix [2, 3] (and [1, 2, 3]) recurs at the start: propose what
    # followed it there
    assert d.propose(req, 3) == [4, 9, 9]
    # k beyond the known continuation pads with the last draft
    assert d.propose(req, 6) == [4, 9, 9, 1, 2, 3]
    # no match anywhere: repeat the last token
    req2 = Request(rid=1, prompt=[5, 6, 7], max_new_tokens=4)
    assert d.propose(req2, 2) == [7, 7]
    # output extends the searchable context
    req3 = Request(rid=2, prompt=[8, 1, 2], max_new_tokens=4)
    req3.output = [3, 8, 1, 2]
    assert d.propose(req3, 2) == [3, 8]


def test_make_drafter_resolution():
    assert isinstance(make_drafter("ngram", CFG), NgramDrafter)
    assert make_drafter("ngram:5", CFG).max_n == 5
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("bogus", CFG)
    with pytest.raises(ValueError, match="unknown draft arch"):
        make_drafter("model:not-an-arch", CFG)


def test_draft_model_drafter_identity_and_vocab_guard(params):
    """A small registry-style draft model proposes through the static
    Engine; the verified stream still matches the baseline exactly.
    Mismatched vocabularies are rejected at construction."""
    draft_cfg = ModelConfig(
        name="toy-draft", family="dense", n_layers=1, d_model=16, n_heads=2,
        n_kv=1, head_dim=8, d_ff=32, vocab=61,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32",
    )
    drafter = DraftModelDrafter(draft_cfg, CFG, key=jax.random.PRNGKey(3))
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, 61, 6).tolist()
    base, _ = _run(params, [prompt], max_new=4)
    got, eng = _run(params, [prompt], max_new=4, spec_k=2, drafter=drafter)
    assert got == base
    assert eng.stats.drafted_tokens > 0

    bad_cfg = ModelConfig(
        name="toy-bad-vocab", family="dense", n_layers=1, d_model=16,
        n_heads=2, n_kv=1, head_dim=8, d_ff=32, vocab=97,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32",
    )
    with pytest.raises(ValueError, match="vocab"):
        DraftModelDrafter(bad_cfg, CFG)


def test_spec_requires_greedy(params):
    with pytest.raises(ValueError, match="greedy"):
        ContinuousBatchingEngine(
            CFG, params=params,
            pcfg=PagedServeConfig(spec_k=2, temperature=0.7))


# ---------------------------------------------------------------------------
# tp=2 (forced devices, subprocess)
# ---------------------------------------------------------------------------

_TP_SPEC_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.models import build
    from repro.serving import ContinuousBatchingEngine, PagedServeConfig

    assert len(jax.devices()) >= 2, jax.devices()

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=61,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32")
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 61, n).tolist() for n in (3, 9, 17)]

    def stream(tp, chunk, spec_k):
        eng = ContinuousBatchingEngine(cfg, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=3,
                                  max_seq_len=32, tp=tp, prefill_chunk=chunk,
                                  spec_k=spec_k))
        reqs = [eng.submit(p, max_new_tokens=5, arrival_step=i)
                for i, p in enumerate(prompts)]
        done = eng.run()
        return [done[r.rid] for r in reqs]

    base = stream(1, 0, 0)
    assert stream(2, 0, 2) == base, "tp2 spec_k=2 diverged"
    assert stream(2, 8, 4) == base, "tp2 chunked spec_k=4 diverged"
    print("TP-SPEC-IDENTICAL-OK")
""")


@pytest.mark.slow
def test_tp2_spec_token_identical_forced_devices():
    """Speculative decoding under tp=2 (+ chunked prefill) on a forced
    8-device CPU mesh is greedy-token-identical to the tp=1 non-spec
    engine.  Subprocess: the forced device count must predate jax."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _TP_SPEC_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "TP-SPEC-IDENTICAL-OK" in proc.stdout
