"""Public serving API tests: options, factory, handles, CLI shims.

Pins the redesigned surface: ``repro.serving.__all__`` is exactly the
six supported names; ``ServeOptions.from_legacy`` lifts the old config
classes with a DeprecationWarning and round-trips field-for-field; the
launcher's deprecated flag spellings emit ONE consolidated warning and
produce ServeOptions identical to the ``--opt KEY=VAL`` replacement
(behavioral equivalence of the shim, not just a warning); ``stream()``
yields exactly the tokens ``run()`` commits, interleaved with
well-formed events; ``SubmitHandle`` drives/cancels/traces while
delegating every Request attribute.
"""
import warnings

import pytest

import repro.serving as serving
from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.serving import (
    ContinuousBatchingEngine,
    Engine,
    PagedServeConfig,
    RequestState,
    ServeConfig,
    ServeOptions,
    SubmitHandle,
    build_engine,
)
from repro.serving.observability import TERMINAL_EVENTS, check_request_events

CFG = ModelConfig(
    name="api-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=1, head_dim=16, d_ff=64, vocab=64,
    numerics=NumericsConfig(mode="f32"),
    act_dtype="float32", param_dtype="float32",
)

OPTS = ServeOptions(max_new_tokens=4, block_size=4, num_blocks=32,
                    max_slots=2, max_seq_len=32)


@pytest.fixture(scope="module")
def params():
    return Engine(CFG).params


def test_public_surface_is_exactly_six_names():
    assert set(serving.__all__) == {
        "Engine", "ContinuousBatchingEngine", "ServeOptions",
        "SubmitHandle", "TraceRecorder", "MetricsRegistry",
    }
    for name in serving.__all__:
        assert getattr(serving, name) is not None


def test_from_legacy_warns_and_round_trips():
    pcfg = PagedServeConfig(block_size=8, num_blocks=64, max_slots=3,
                            spec_k=2, preemption="recompute", trace=False)
    with pytest.warns(DeprecationWarning):
        opts = ServeOptions.from_legacy(pcfg)
    assert opts.engine == "continuous"
    assert opts.paged() == pcfg  # field-for-field round trip

    scfg = ServeConfig(max_new_tokens=9, temperature=0.5, seed=3,
                       time_steps=True)
    with pytest.warns(DeprecationWarning):
        opts = ServeOptions.from_legacy(scfg, seed=7)  # override applies
    assert opts.engine == "static"
    assert opts.static() == ServeConfig(max_new_tokens=9, temperature=0.5,
                                        seed=7, time_steps=True)

    with pytest.raises(TypeError):
        ServeOptions.from_legacy(object())


def test_legacy_serve_flags_warn_once_and_match_opt_spelling():
    from repro.launch.serve import make_parser, options_from_args

    base = ["--arch", "yi-6b", "--continuous"]
    legacy = make_parser().parse_args(
        base + ["--spec-k", "3", "--preemption", "recompute",
                "--priority", "2", "--deadline-s", "9.5"])
    modern = make_parser().parse_args(
        base + ["--opt", "spec_k=3", "--opt", "preemption=recompute",
                "--opt", "priority=2", "--opt", "deadline_s=9.5"])

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy_opts = options_from_args(legacy)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, "legacy flags must emit ONE consolidated warning"
    msg = str(dep[0].message)
    for flag in ("--spec-k", "--preemption", "--priority", "--deadline-s"):
        assert flag in msg

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        modern_opts = options_from_args(modern)
    assert not [w for w in caught if issubclass(w.category, DeprecationWarning)]

    # the shim is behavior-identical, not merely tolerated
    assert legacy_opts == modern_opts
    assert legacy_opts.spec_k == 3 and legacy_opts.preemption == "recompute"
    assert legacy_opts.priority == 2 and legacy_opts.deadline_s == 9.5


def test_opt_flag_rejects_unknown_keys():
    from repro.launch.serve import make_parser, options_from_args

    args = make_parser().parse_args(
        ["--arch", "yi-6b", "--opt", "not_a_field=1"])
    with pytest.raises(SystemExit):
        options_from_args(args)


def test_build_engine_dispatch(params):
    eng = build_engine(CFG, OPTS, params=params)
    assert isinstance(eng, ContinuousBatchingEngine)  # auto: dense -> paged
    stat = build_engine(
        CFG, ServeOptions(engine="static"), params=params)
    assert isinstance(stat, Engine)
    with pytest.raises(ValueError):
        build_engine(CFG, ServeOptions(engine="quantum"), params=params)


def test_submit_handle_result_trace_and_delegation(params):
    eng = build_engine(CFG, OPTS, params=params)
    h = eng.submit([1, 2, 3], max_new_tokens=4)
    assert isinstance(h, SubmitHandle)
    # delegation: Request attributes read through the handle
    assert h.rid == h.request.rid
    assert h.max_new_tokens == 4
    assert h.state is RequestState.WAITING
    out = h.result()
    assert out == h.request.output and len(out) == 4
    assert h.state is RequestState.FINISHED
    evs = h.trace()
    check_request_events(evs)
    assert evs[-1].etype == "FINISH"
    bd = h.breakdown()
    assert bd.terminal == "FINISH"
    # result() after finish is a no-op returning the same list
    assert h.result() == out


def test_submit_handle_cancel(params):
    eng = build_engine(CFG, OPTS, params=params)
    h = eng.submit([1, 2, 3], max_new_tokens=20)
    eng.step()
    h.cancel()
    assert h.state is RequestState.CANCELLED
    assert h.trace()[-1].etype == "CANCEL"
    # engine.cancel also accepts the handle itself (idempotent)
    eng.cancel(h)
    assert h.state is RequestState.CANCELLED


def test_stream_matches_run(params):
    ref = build_engine(CFG, OPTS, params=params)
    expect = ref.submit([9, 8, 7], max_new_tokens=6).result()

    eng = build_engine(CFG, OPTS, params=params)
    toks, etypes = [], []
    for item in eng.stream([9, 8, 7], max_new_tokens=6):
        if "tokens" in item:
            toks.extend(item["tokens"])
        else:
            etypes.append(item["event"].etype)
    assert toks == expect, "stream() must yield exactly run()'s tokens"
    assert etypes[0] == "SUBMIT"
    assert etypes[-1] in TERMINAL_EVENTS
    assert sum(e in TERMINAL_EVENTS for e in etypes) == 1


def test_stats_facade_quantiles_route_through_registry(params):
    eng = build_engine(CFG, OPTS, params=params)
    eng.submit([1, 2, 3], max_new_tokens=4).result()
    assert eng.stats._registry is eng.metrics
    hist = eng.metrics.histogram("serve_step_latency_seconds")
    assert eng.stats.latency_p50() == hist.quantile(0.50)
    assert eng.stats.latency_p95() == hist.quantile(0.95)
    # a benchmark-style reset rebinds on the next step and keeps the
    # registry reading the LIVE stats object
    from repro.serving import ServeStats

    eng.stats = ServeStats()
    eng.submit([1, 2, 3], max_new_tokens=2).result()
    assert eng.stats._registry is eng.metrics
    assert eng.metrics.value("serve_steps_total") == eng.stats.steps
