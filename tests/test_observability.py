"""Observability-layer tests: event grammar, breakdowns, exporters.

The property test (shim-compatible, tests/_hypothesis_shim.py) drives
randomized request streams — staggered arrivals, priorities, deadlines,
mid-run client cancels, speculative bursts and forced pool pressure
under ``preemption="recompute"`` — through a REAL tiny engine on a fake
step-counting clock, and asserts every request's event sequence is
well-formed (SUBMIT first, PREEMPT/RESUME alternating, exactly one
terminal event) and that the derived queue/prefill/decode/parked
breakdown sums EXACTLY to the request's submit->terminal wall time.

Structural tests pin the export formats: Chrome trace_event JSON
(Perfetto-loadable shape), JSON-lines round-trip + the CI schema
checker, and Prometheus text exposition syntax.

``REPRO_PROP_MULT`` multiplies ``max_examples`` (CI stress runs 10x);
``REPRO_PROP_SEED`` offsets the derived rng streams.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.serving import (
    ContinuousBatchingEngine,
    MetricsRegistry,
    PagedServeConfig,
    TraceRecorder,
)
from repro.serving.observability import (
    EVENT_SCHEMA,
    TERMINAL_EVENTS,
    TraceEvent,
    TraceInvariantError,
    check_prom_file,
    check_request_events,
    check_trace_file,
    load_jsonl,
    macs_per_token_by_mode,
    validate_event,
)

_MULT = int(os.environ.get("REPRO_PROP_MULT", "1"))
_SEED = int(os.environ.get("REPRO_PROP_SEED", "0"))

CFG = ModelConfig(
    name="obs-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv=1, head_dim=16, d_ff=64, vocab=64,
    numerics=NumericsConfig(mode="f32"),
    act_dtype="float32", param_dtype="float32",
)

# fake clock: the tests advance one "second" per engine step, so every
# breakdown below is deterministic in step units
_CLOCK = {"t": 0.0}

# engines are cached per configuration so XLA compiles amortize across
# hypothesis examples (each engine keeps its own jit cache)
_ENGINES = {}


def _engine(preemption="off", spec_k=0, prefill_chunk=0):
    key = (preemption, spec_k, prefill_chunk)
    if key not in _ENGINES:
        _ENGINES[key] = ContinuousBatchingEngine(
            CFG,
            pcfg=PagedServeConfig(
                block_size=4,
                num_blocks=16 if preemption == "recompute" else 64,
                max_slots=2, max_seq_len=48,
                spec_k=spec_k, prefill_chunk=prefill_chunk,
                preemption=preemption,
                clock=lambda: _CLOCK["t"],
            ),
        )
    return _ENGINES[key]


# -- event-sequence property ------------------------------------------------


@settings(max_examples=6 * _MULT, deadline=None)
@given(st.integers(0, 10**9))
def test_event_streams_well_formed(seed):
    rng = np.random.default_rng(_SEED * 7919 + seed)
    preemption = "recompute" if rng.integers(2) else "off"
    spec_k = 2 if rng.integers(2) else 0
    chunk = 4 if (spec_k == 0 and rng.integers(2)) else 0
    eng = _engine(preemption, spec_k, chunk)
    eng.trace.clear()  # engine is reused; examples assert on own events
    base = eng.current_step
    handles = []
    for _ in range(int(rng.integers(2, 5))):
        plen = int(rng.choice([3, 6, 11]))
        handles.append(eng.submit(
            rng.integers(0, CFG.vocab, plen).tolist(),
            max_new_tokens=int(rng.integers(2, 8)),
            arrival_step=base + int(rng.integers(0, 3)),
            priority=int(rng.integers(0, 2)),
            deadline_s=float(rng.integers(4, 40)) if rng.integers(2) else None,
        ))
    steps = 0
    while eng.scheduler.has_work():
        eng.step()
        _CLOCK["t"] += 1.0
        steps += 1
        if steps == 3 and rng.integers(2):
            eng.cancel(handles[0])
        assert steps < 500, "engine did not drain"

    eng.trace.validate()  # recorder-level grammar check over every rid
    for h in handles:
        evs = h.trace()
        check_request_events(evs)
        assert evs[0].etype == "SUBMIT"
        assert sum(e.etype in TERMINAL_EVENTS for e in evs) == 1
        assert evs[-1].etype in TERMINAL_EVENTS
        pr = [e.etype for e in evs if e.etype in ("PREEMPT", "RESUME")]
        assert pr[::2] == ["PREEMPT"] * len(pr[::2])
        assert pr[1::2] == ["RESUME"] * len(pr[1::2])
        # the telescoping breakdown covers the lifetime exactly: the
        # phase buckets sum to submit->terminal wall time, no residue
        bd = h.breakdown()
        total = bd.queue_s + bd.prefill_s + bd.decode_s + bd.parked_s
        assert total == pytest.approx(bd.total_s, abs=1e-9)
        assert bd.total_s == pytest.approx(evs[-1].t - evs[0].t, abs=1e-9)
        if bd.terminal == "FINISH" and h.output:
            assert bd.first_token_s is not None
            assert 0.0 <= bd.first_token_s <= bd.total_s


# -- schema / grammar rejection ---------------------------------------------


def test_schema_rejects_malformed_events():
    with pytest.raises(TraceInvariantError):
        validate_event(TraceEvent("NOT_A_TYPE", 0, 0, 0.0, {"out_len": 0}))
    with pytest.raises(TraceInvariantError):  # missing out_len
        validate_event(TraceEvent("DECODE", 0, 0, 0.0, {"new_tokens": 1}))
    validate_event(TraceEvent("DECODE", 0, 0, 0.0,
                              {"new_tokens": 1, "out_len": 3}))  # ok
    # extra keys (occupancy stamps etc.) are allowed
    validate_event(TraceEvent("FINISH", 0, 0, 0.0,
                              {"out_len": 3, "free_blocks": 9}))


def test_grammar_rejects_malformed_sequences():
    sub = TraceEvent("SUBMIT", 0, 0, 0.0, {"prompt_len": 4, "max_new": 4})
    adm = TraceEvent("ADMIT", 0, 1, 1.0,
                     {"slot": 0, "blocks": 1, "cached_len": 0})
    fin = TraceEvent("FINISH", 0, 3, 3.0, {"out_len": 4})
    res = TraceEvent("RESUME", 0, 2, 2.0,
                     {"slot": 0, "blocks": 1, "parked_steps": 1})
    check_request_events([sub, adm, fin])  # baseline is legal
    with pytest.raises(TraceInvariantError):
        check_request_events([adm, fin])  # ADMIT before SUBMIT
    with pytest.raises(TraceInvariantError):
        check_request_events([sub, adm, fin, fin])  # two terminals
    with pytest.raises(TraceInvariantError):
        check_request_events([sub, adm, res, fin])  # RESUME without PREEMPT
    with pytest.raises(TraceInvariantError):
        # timestamps must be non-decreasing
        check_request_events([
            sub,
            TraceEvent("ADMIT", 0, 1, -1.0,
                       {"slot": 0, "blocks": 1, "cached_len": 0}),
            fin,
        ])


# -- exporters ---------------------------------------------------------------


@pytest.fixture(scope="module")
def traced_run():
    eng = _engine()
    eng.trace.clear()
    hs = [eng.submit([1, 2, 3], max_new_tokens=4),
          eng.submit([4, 5, 6, 7, 8, 9], max_new_tokens=3)]
    while eng.scheduler.has_work():
        eng.step()
        _CLOCK["t"] += 1.0
    return eng, hs


def test_chrome_trace_structure(tmp_path, traced_run):
    eng, _ = traced_run
    path = tmp_path / "trace.json"
    eng.trace.to_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    # Perfetto essentials: complete slices carry ts+dur, instants carry
    # a scope, metadata names the per-request tracks
    kinds = {e["ph"] for e in evs}
    assert {"X", "i", "M"} <= kinds
    for e in evs:
        assert {"ph", "pid", "tid", "name"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
        if e["ph"] == "i":
            assert e["s"] in ("t", "p", "g")
        if e["ph"] == "M":
            assert e["name"] == "thread_name"
    rids = {h.rid for h in traced_run[1]}
    assert {e["tid"] for e in evs if e["ph"] == "X"} <= rids


def test_jsonl_roundtrip_and_schema_checker(tmp_path, traced_run):
    eng, hs = traced_run
    path = tmp_path / "trace.jsonl"
    eng.trace.to_jsonl(str(path))
    loaded = load_jsonl(str(path))
    assert [e.to_dict() for e in loaded] == [
        e.to_dict() for e in eng.trace.events]
    counts = check_trace_file(str(path))
    assert counts["requests"] == len(hs)
    assert counts["terminal"] == len(hs)


def test_prometheus_text_syntax(tmp_path, traced_run):
    eng, _ = traced_run
    text = eng.metrics.to_prometheus_text()
    path = tmp_path / "metrics.prom"
    path.write_text(text)
    n = check_prom_file(str(path))  # raises on any malformed line
    assert n > 0
    assert "# TYPE serve_step_latency_seconds histogram" in text
    assert 'le="+Inf"' in text
    # live-sourced counters reflect engine state at scrape time
    assert eng.metrics.value("serve_steps_total") == eng.stats.steps


def test_admit_schema_requires_cached_len():
    with pytest.raises(TraceInvariantError):
        validate_event(TraceEvent("ADMIT", 0, 0, 0.0, {"slot": 0, "blocks": 1}))


def test_prom_gate_requires_prefix_cache_families(tmp_path):
    # a serving export without the prefix-cache counters is rejected;
    # files with no serve_ families at all are exempt from the gate
    p = tmp_path / "m.prom"
    p.write_text("serve_steps_total 3\n")
    with pytest.raises(TraceInvariantError):
        check_prom_file(str(p))
    p.write_text("unrelated_metric 1\n")
    assert check_prom_file(str(p)) == 1
    p.write_text(
        "serve_steps_total 3\n"
        "serve_prefix_cache_hits_total 0\n"
        "serve_prefix_cache_misses_total 0\n"
        "serve_prefix_cache_evictions_total 0\n"
    )
    assert check_prom_file(str(p)) == 4


def test_latency_summary_sane(traced_run):
    eng, hs = traced_run
    s = eng.trace.latency_summary()
    assert s["requests"] == len(hs)
    # the fake clock ticks once per engine step, so a request whose
    # admit+prefill landed inside the submit step has ttft exactly 0.0
    assert 0.0 <= s["first_token_p50_s"] <= s["total_p95_s"]
    assert s["total_p95_s"] > 0.0
    assert s["total_p50_s"] <= s["total_p95_s"]
    for h in hs:
        ttft, total = eng.trace.latency(h.rid)
        assert 0.0 <= ttft <= total


# -- metrics registry --------------------------------------------------------


def test_registry_instruments_and_labels():
    reg = MetricsRegistry()
    reg.counter("c", "help text").inc()
    reg.counter("c").inc(2)
    assert reg.value("c") == 3.0
    with pytest.raises(AssertionError):
        reg.counter("c").inc(-1)
    reg.gauge("g", mode="plam").set(0.5)
    reg.gauge("g", mode="f32").set(0.25)
    assert reg.value("g", mode="plam") == 0.5
    assert reg.value("g", mode="f32") == 0.25
    h = reg.histogram("h")
    for v in (0.001, 0.002, 0.003, 0.4):
        h.observe(v)
    assert h.count == 4
    assert h.quantile(0.5) == pytest.approx(0.0025)
    text = reg.to_prometheus_text()
    assert '# HELP c help text' in text
    assert 'g{mode="plam"} 0.5' in text
    assert 'h_count 4' in text


def test_registry_sources_and_snapshot_hooks():
    reg = MetricsRegistry()
    box = {"v": 1.0, "xs": [0.1]}
    reg.counter("src_total").set_source(lambda: box["v"])
    reg.histogram("src_hist").set_source(lambda: box["xs"])
    with pytest.raises(AssertionError):
        reg.counter("src_total").inc()  # sourced instruments are read-only
    box["v"] = 7.0
    box["xs"].append(0.3)
    assert reg.value("src_total") == 7.0
    assert reg.histogram("src_hist").count == 2
    fired = []
    reg.every(5, lambda r: fired.append(r.value("src_total")))
    for step in range(1, 11):
        reg.tick(step)
    assert fired == [7.0, 7.0]  # steps 5 and 10
    snap = reg.snapshot()
    assert snap["src_total"] == 7.0
    assert snap["src_hist"]["count"] == 2


def test_macs_by_mode_attribution():
    plam_cfg = CFG.with_numerics(NumericsConfig(mode="plam_sim", n=16, es=1))
    macs = macs_per_token_by_mode(plam_cfg)
    assert set(macs) == {"plam_sim:16:1"}
    from repro.numerics.calibrate import site_macs

    assert macs["plam_sim:16:1"] == pytest.approx(
        sum(site_macs(plam_cfg).values()))
    # a split policy attributes per resolved site mode
    from repro.core.policy import parse_policy

    split = CFG.with_numerics(
        parse_policy("default=plam_sim:16:1, lm_head=f32"))
    split_macs = macs_per_token_by_mode(split)
    assert set(split_macs) == {"plam_sim:16:1", "f32"}
    assert sum(split_macs.values()) == pytest.approx(
        sum(site_macs(split).values()))


def test_engine_exports_mode_mac_counters(traced_run):
    eng, _ = traced_run
    text = eng.metrics.to_prometheus_text()
    assert 'serve_macs_total{mode="f32"}' in text
    generated = eng.stats.prefill_tokens + eng.stats.generated_tokens
    per_tok = macs_per_token_by_mode(CFG)["f32"]
    assert eng.metrics.value("serve_macs_total", mode="f32") == (
        pytest.approx(per_tok * generated))
