"""Beyond-paper optimization correctness: these change PERFORMANCE,
never semantics (or change them in documented, tested ways)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig, nmatmul, nquant_weight
from repro.models import build
from repro.models.attention import attn_core, attn_core_blockwise
from repro.models.common import causal_mask, rmsnorm
from repro.models.moe import moe_apply, moe_init

F32 = NumericsConfig(mode="f32")


def test_prequantized_weights_value_identical():
    """quantize-on-read == prequantize-then-read, bit for bit."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    base = NumericsConfig(mode="posit_quant")
    pre = dataclasses.replace(base, prequantized_weights=True)
    wq = nquant_weight(w, base)  # project onto the grid once
    a = np.asarray(nmatmul(x, w, base))
    b = np.asarray(nmatmul(x, wq, pre))
    np.testing.assert_array_equal(a, b)


def test_bf16_carrier_close_to_f32_carrier():
    """Double quantization (posit16 then bf16) stays within bf16 ulp."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    f = np.asarray(nmatmul(x, w, NumericsConfig(mode="posit_quant")), np.float32)
    b = np.asarray(nmatmul(x, w, NumericsConfig(mode="posit_quant", carrier="bf16")), np.float32)
    np.testing.assert_allclose(b, f, rtol=3e-2, atol=3e-2)


def test_bf16_carrier_gradients_are_bf16_and_finite():
    cfg = NumericsConfig(mode="posit_quant", carrier="bf16", prequantized_weights=True)
    x = jnp.ones((4, 8), jnp.bfloat16)
    w = jnp.ones((8, 4), jnp.bfloat16)
    g = jax.grad(lambda x_: jnp.sum(nmatmul(x_, w, cfg).astype(jnp.float32)))(x)
    assert g.dtype == jnp.bfloat16
    assert np.all(np.isfinite(np.asarray(g, np.float32)))


def test_rmsnorm_custom_vjp_matches_autodiff():
    def ref(scale, x, eps=1e-6):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((3, 5, 16)).astype(np.float32))
    p = {"scale": jnp.asarray(rng.standard_normal(16).astype(np.float32))}
    g1 = jax.grad(lambda p_, x_: jnp.sum(jnp.sin(rmsnorm(p_, x_))), argnums=(0, 1))(p, x)
    g2 = jax.grad(lambda p_, x_: jnp.sum(jnp.sin(ref(p_["scale"], x_))), argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_blockwise_attention_matches_reference(block, causal):
    rng = np.random.default_rng(3)
    b, s, h, kvh, hd = 2, 64, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    mask = causal_mask(s, s) if causal else jnp.ones((s, s), bool)
    ref = np.asarray(attn_core(q, k, v, mask))
    out = np.asarray(attn_core_blockwise(q, k, v, causal=causal, block=block))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_blockwise_attention_grads():
    rng = np.random.default_rng(4)
    b, s, h, kvh, hd = 1, 32, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)).astype(np.float32))
    g1 = jax.grad(lambda q_: jnp.sum(jnp.sin(attn_core(q_, k, v, causal_mask(s, s)))))(q)
    g2 = jax.grad(lambda q_: jnp.sum(jnp.sin(
        attn_core_blockwise(q_, k, v, causal=True, block=8))))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-5, atol=2e-5)


def test_grouped_moe_dispatch_matches_ungrouped_high_capacity():
    """With capacity >> need, grouped and global dispatch agree exactly
    (no drops on either path)."""
    rng = np.random.default_rng(5)
    e, k, d, ff = 8, 2, 16, 32
    p = moe_init(jax.random.PRNGKey(0), d, e, ff, 0, ff, glu=True)
    x = jnp.asarray(rng.standard_normal((2, 16, d)).astype(np.float32))
    a = np.asarray(moe_apply(p, x, F32, n_experts=e, top_k=k, capacity_factor=50.0, groups=1))
    b = np.asarray(moe_apply(p, x, F32, n_experts=e, top_k=k, capacity_factor=50.0, groups=4))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_grouped_moe_in_model_trains():
    cfg = ModelConfig(
        name="moe-g", family="moe", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        head_dim=16, d_ff=128, vocab=64, n_experts=4, top_k=2, moe_d_ff=32,
        moe_groups=4, numerics=NumericsConfig(mode="posit_quant"),
    )
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(6).integers(0, 64, (2, 32)).astype(np.int32)),
        "labels": jnp.asarray(np.random.default_rng(7).integers(0, 64, (2, 32)).astype(np.int32)),
    }
    loss, grads = jax.jit(jax.value_and_grad(api.train_loss))(params, batch)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in jax.tree.leaves(grads))


def test_flash_block_in_model_matches_reference_path():
    base = ModelConfig(
        name="fb", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        head_dim=16, d_ff=128, vocab=97, numerics=NumericsConfig(mode="f32"),
    )
    flash = dataclasses.replace(base, flash_block=16)
    a_api, f_api = build(base), build(flash)
    params = a_api.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(8).integers(0, 97, (2, 32)).astype(np.int32)),
        "labels": jnp.asarray(np.random.default_rng(9).integers(0, 97, (2, 32)).astype(np.int32)),
    }
    la = float(jax.jit(a_api.train_loss)(params, batch))
    lf = float(jax.jit(f_api.train_loss)(params, batch))
    assert abs(la - lf) < 1e-4, (la, lf)
