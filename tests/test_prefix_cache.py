"""Block-level prefix caching (PR 8 acceptance bar).

Prefix caching is an EXECUTION STRATEGY, not a model: admission serving
the leading full blocks of a prompt from the content-addressed cache
and prefilling only the miss suffix must produce exactly the greedy
tokens a cache-off engine produces, across chunked/unchunked prefill,
spec_k on/off and tp=1/2 (the tp=2 cases run in a subprocess with
forced host devices, like tests/test_preemption.py).  Alongside token
identity this file pins the allocator's content-addressing semantics
(chain hashes, refcounted acquire/release, LRU eviction ordering),
copy-on-write on fully-cached prompts, forced-eviction recovery,
preempt-then-resume hitting the victim's own published prefix, and the
batched-scrub coalescing (one jitted dispatch per step, not one per
retire/evict event).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.models import build
from repro.serving import (
    BlockAllocator,
    ContinuousBatchingEngine,
    PagedServeConfig,
)

CFG = ModelConfig(
    name="toy-prefix", family="dense", n_layers=2, d_model=32, n_heads=4,
    n_kv=2, head_dim=8, d_ff=64, vocab=61,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def params():
    return build(CFG).init(jax.random.PRNGKey(0))


def _engine(params, *, prefix_cache, chunk=0, spec=0, num_blocks=64,
            max_slots=4, preemption="off"):
    return ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=num_blocks,
                              max_slots=max_slots, max_seq_len=48,
                              prefill_chunk=chunk, spec_k=spec,
                              preemption=preemption,
                              prefix_cache=prefix_cache))


# ---------------------------------------------------------------------------
# allocator: content addressing, refcounts, LRU eviction
# ---------------------------------------------------------------------------

def test_match_prefix_chain_hash_semantics():
    al = BlockAllocator(16, 4, prefix_cache=True)
    toks = list(range(11))  # 2 full blocks + a partial tail
    blocks = al.allocate(3)
    al.register(toks, blocks)
    # only FULL blocks are addressable; the partial tail never is
    assert al.match_prefix(toks) == blocks[:2]
    assert al.match_prefix(toks[:8]) == blocks[:2]
    assert al.match_prefix(toks[:7]) == blocks[:1]
    assert al.match_prefix(toks[:3]) == []
    # chain hashing is position-dependent: the same 4 tokens under a
    # different parent prefix must NOT resolve to the cached block
    assert al.match_prefix([99] * 4 + toks[4:8]) == []
    # a diverging second block still hits the shared first block
    assert al.match_prefix(toks[:4] + [99] * 4) == blocks[:1]


def test_release_parks_registered_blocks_and_acquire_repins():
    al = BlockAllocator(16, 4, prefix_cache=True)
    toks = list(range(8))
    blocks = al.allocate(2)
    al.register(toks, blocks)
    assert al.release(blocks) == []  # registered: parked, NOT freed
    assert al.num_cached_idle == 2 and al.num_referenced == 0
    assert al.num_available == al.num_blocks - 1
    # a hit re-pins the idle blocks: no longer evictable
    hits = al.match_prefix(toks)
    al.acquire(hits)
    assert al.num_cached_idle == 0
    assert all(al.refcount(b) == 1 for b in hits)
    # unregistered blocks go straight back to the free list
    other = al.allocate(1)
    assert al.release(other) == other


def test_lru_eviction_order_and_drain():
    al = BlockAllocator(8, 4, prefix_cache=True)  # 7 allocatable
    a = al.allocate(2)
    b = al.allocate(2)
    al.register(list(range(8)), a)
    al.register(list(range(100, 108)), b)
    al.release(a)  # a parked first -> evicted first
    al.release(b)
    assert al.num_free == 3 and al.num_cached_idle == 4
    got = al.allocate(5)  # forces two evictions, oldest-released first
    assert al.evictions == 2
    assert set(al.drain_evicted()) == set(a)
    assert al.drain_evicted() == []  # drain is destructive
    assert set(a) <= set(got)  # the evicted blocks were reused
    assert al.match_prefix(list(range(8))) == []  # a unregistered
    assert al.match_prefix(list(range(100, 108))) == b  # b survives


def test_shared_block_never_freed_while_referenced():
    al = BlockAllocator(16, 4, prefix_cache=True)
    toks = list(range(8))
    owner = al.allocate(2)
    al.register(toks, owner)
    al.acquire(al.match_prefix(toks))  # a second sequence shares them
    assert all(al.refcount(b) == 2 for b in owner)
    with pytest.raises(ValueError, match="shared"):
        al.free(owner)
    assert al.release(owner) == []  # one ref left: still referenced
    assert al.num_cached_idle == 0 and al.num_referenced == 2


def test_prefix_cache_off_is_inert():
    al = BlockAllocator(16, 4)
    blocks = al.allocate(2)
    al.register(list(range(8)), blocks)  # no-op
    assert al.match_prefix(list(range(8))) == []
    assert al.release(blocks) == blocks  # nothing parks on the LRU
    assert al.num_cached == 0 and al.num_cached_idle == 0


# ---------------------------------------------------------------------------
# token identity across the config matrix (tp=1 half; tp=2 is below)
# ---------------------------------------------------------------------------

def _shared_prefix_workload(eng, rng, *, n=4, prefix_len=16, max_new=6):
    shared = rng.integers(0, 61, prefix_len).tolist()
    handles = []
    for i in range(n):
        tail = rng.integers(0, 61, 3 + i).tolist()
        # stagger arrivals past the longest chunked prefill: registration
        # happens at prefill completion, so back-to-back arrivals would
        # all be admitted (blocks reserved) before any prefix is published
        handles.append(eng.submit(shared + tail, max_new_tokens=max_new,
                                  arrival_step=i * 10))
    done = eng.run()
    return [done[h.rid] for h in handles]


@pytest.mark.parametrize("spec", [0, 2])
@pytest.mark.parametrize("chunk", [0, 4])
def test_shared_prefix_token_identical_cache_on_off(params, chunk, spec):
    rng = np.random.default_rng(0)
    off = _shared_prefix_workload(_engine(params, prefix_cache=False,
                                          chunk=chunk, spec=spec),
                                  np.random.default_rng(0))
    eng = _engine(params, prefix_cache=True, chunk=chunk, spec=spec)
    on = _shared_prefix_workload(eng, np.random.default_rng(0))
    assert on == off, f"cache changed the stream (chunk={chunk} spec={spec})"
    al = eng.allocator
    assert al.hits > 0 and al.tokens_saved > 0, "cache never hit"
    assert eng.metrics.value("serve_prefix_cache_hits_total") == al.hits
    assert eng.metrics.value("serve_prefill_tokens_saved_total") == al.tokens_saved
    del rng


def test_identical_prompt_triggers_cow_and_stays_identical(params):
    """A block-aligned prompt resubmitted verbatim hits EVERY block;
    the capped last token lands mid-block, so the tail hit must be
    copied out before the recompute write — the copy-on-write path."""
    prompt = np.random.default_rng(1).integers(0, 61, 16).tolist()  # 4 blocks

    def run(prefix_cache):
        eng = _engine(params, prefix_cache=prefix_cache)
        a = eng.submit(prompt, max_new_tokens=6)
        b = eng.submit(prompt, max_new_tokens=6, arrival_step=2)
        done = eng.run()
        return [done[a.rid], done[b.rid]], eng

    off, _ = run(False)
    on, eng = run(True)
    assert on == off
    assert off[0] == off[1]  # same prompt, greedy: same stream
    assert eng.allocator.cow_copies > 0, "fully-cached prompt never COWed"
    # the shared source block was pinned during the copy and released
    # after: nothing leaks once both requests retire
    assert eng.allocator.num_referenced == 0


def test_forced_eviction_keeps_streams_identical(params):
    """A pool too small to keep every retired prefix cached: later
    admissions evict idle cached blocks (scrub-then-reuse), and the
    evicted prefix resubmitted afterwards simply misses and recomputes."""
    rng = np.random.default_rng(2)
    pa = rng.integers(0, 61, 16).tolist()
    pb = rng.integers(0, 61, 16).tolist()

    def run(prefix_cache):
        # 9 allocatable blocks: one request needs 6 (16 prompt + 6 new
        # tokens), so pb's admission must evict part of pa's parked
        # 4-block prefix — and the resubmitted pa, its chain head gone,
        # misses from block 0 and evicts the rest
        eng = _engine(params, prefix_cache=prefix_cache, num_blocks=10,
                      max_slots=1)
        outs = []
        for p in (pa, pb, pa):
            h = eng.submit(p, max_new_tokens=6)
            outs.append(eng.run()[h.rid])
        return outs, eng

    off, _ = run(False)
    on, eng = run(True)
    assert on == off
    assert eng.allocator.evictions > 0, "pool pressure never evicted"
    assert on[0] == on[2]  # same prompt, greedy: same stream
    assert eng.metrics.value("serve_prefix_cache_evictions_total") == \
        eng.allocator.evictions


def test_preempt_resume_hits_own_published_prefix(params):
    """Under recompute preemption a victim's registered blocks park on
    the LRU; its resume walks the cache and reuses them instead of
    recomputing the whole committed context — and still matches the
    uninterrupted cache-off stream."""
    rng = np.random.default_rng(3)
    pa = rng.integers(0, 61, 8).tolist()
    pb = rng.integers(0, 61, 8).tolist()

    def run(prefix_cache, num_blocks=10, max_slots=2):
        eng = _engine(params, prefix_cache=prefix_cache,
                      num_blocks=num_blocks, max_slots=max_slots,
                      preemption="recompute")
        a = eng.submit(pa, max_new_tokens=12)
        b = eng.submit(pb, max_new_tokens=12, arrival_step=1)
        done = eng.run()
        return [done[a.rid], done[b.rid]], eng

    off, _ = run(False, num_blocks=64)  # uninterrupted reference
    on, eng = run(True)
    assert on == off
    assert eng.stats.preemptions > 0, "pool pressure never evicted"
    assert eng.allocator.hits > 0, "resume never hit the cache"
    assert not eng.scheduler.has_work()
    assert eng.allocator.num_referenced == 0


# ---------------------------------------------------------------------------
# batched scrubs: one dispatch per step, not one per event
# ---------------------------------------------------------------------------

def test_scrubs_coalesce_into_one_dispatch_per_step(params):
    """Three same-step retires, each with a stale prefill-padding tail,
    must produce exactly ONE jitted scrub dispatch (at the end-of-step
    flush) — the per-event dispatches were coalesced."""
    eng = _engine(params, prefix_cache=False, max_slots=3)
    calls = []
    orig = eng._scrub_fn

    def counting(*args):
        calls.append(eng.current_step)
        return orig(*args)

    eng._scrub_fn = counting
    rng = np.random.default_rng(4)
    # 5-token prompts pad to 8: positions [5, 8) stay stale => every
    # retire reports a non-empty scrub set
    hs = [eng.submit(rng.integers(0, 61, 5).tolist(), max_new_tokens=3)
          for _ in range(3)]
    eng.run()
    finish = {h.finished_step for h in hs}
    assert len(finish) == 1, "requests did not retire in the same step"
    assert calls.count(finish.pop()) == 1, (
        f"expected one coalesced scrub dispatch, saw {calls}")
    assert eng._scrub_pending == []


def test_scrubbed_pool_reads_zero_after_retire(params):
    """The deferred scrub still lands before the step ends: the retired
    request's stale tail — prefill padding past the last committed
    token — reads back as zeros once the workload drains.  (Committed
    K/V may persist in freed blocks; retire scrubs only the
    written-but-never-committed range, same as the seed contract.)"""
    eng = _engine(params, prefix_cache=False, num_blocks=8, max_slots=1)
    # prompt 5 pads to 8; max_new=2 commits through position 6, so
    # position 7 stays a stale padding write.  A fresh engine hands the
    # request blocks [1, 2]; position 7 lives in block 2.
    h = eng.submit(np.random.default_rng(5).integers(0, 61, 5).tolist(),
                   max_new_tokens=2)
    eng.run()
    assert h.state.name == "FINISHED"
    assert eng.allocator.num_free == 7
    assert eng._scrub_pending == []
    k = np.asarray(eng._k_pool)[:, 2]
    v = np.asarray(eng._v_pool)[:, 2]
    assert not k.any() and not v.any(), "stale padding tail not scrubbed"


# ---------------------------------------------------------------------------
# tp=2 half of the matrix (forced host devices, subprocess)
# ---------------------------------------------------------------------------

_TP_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.models import build
    from repro.serving import ContinuousBatchingEngine, PagedServeConfig

    assert len(jax.devices()) >= 2, jax.devices()

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=61,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32")
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 61, 16).tolist()
    tails = [rng.integers(0, 61, 3 + i).tolist() for i in range(3)]

    def run(tp, chunk, spec, prefix_cache):
        eng = ContinuousBatchingEngine(cfg, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=64,
                                  max_slots=3, max_seq_len=48, tp=tp,
                                  prefill_chunk=chunk, spec_k=spec,
                                  prefix_cache=prefix_cache))
        # arrivals staggered past the chunked prefill so each request
        # sees the previous one's registered prefix
        hs = [eng.submit(shared + t, max_new_tokens=6, arrival_step=i * 10)
              for i, t in enumerate(tails)]
        done = eng.run()
        return [done[h.rid] for h in hs], eng

    for chunk, spec in ((0, 0), (4, 2)):
        base, _ = run(2, chunk, spec, False)
        on, eng = run(2, chunk, spec, True)
        assert eng.allocator.hits > 0, (chunk, spec)
        assert base == on, (
            f"tp2 prefix cache diverged chunk={chunk} spec={spec}: "
            f"{base} vs {on}")
    print("PREFIX-TP2-OK")
""")


@pytest.mark.slow
def test_tp2_prefix_cache_token_identical_forced_devices():
    """Prefix caching under tp=2 sharding (head-sharded KV pool) is
    greedy-token-identical to the cache-off tp=2 engine, unchunked and
    chunked+speculative.  Subprocess: the forced device count must be
    set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _TP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PREFIX-TP2-OK" in proc.stdout
