"""Data pipeline: determinism (the fault-tolerance contract) + structure."""
import numpy as np

from repro.data.synthetic import DataConfig, classification_dataset, image_dataset, lm_batch


def test_lm_batch_deterministic_across_calls():
    """Same (config, step) -> identical batch: restart replay correctness."""
    cfg = DataConfig(seed=3, vocab=64, seq_len=32, global_batch=4)
    a = lm_batch(cfg, 17)
    b = lm_batch(cfg, 17)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_lm_batch_differs_across_steps_and_seeds():
    cfg = DataConfig(seed=3, vocab=64, seq_len=32, global_batch=4)
    a = lm_batch(cfg, 1)
    b = lm_batch(cfg, 2)
    c = lm_batch(DataConfig(seed=4, vocab=64, seq_len=32, global_batch=4), 1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_lm_batch_labels_are_next_tokens():
    cfg = DataConfig(seed=0, vocab=64, seq_len=16, global_batch=2)
    b = lm_batch(cfg, 0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # label[t] is the next token: tokens[t+1] == labels[t]
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    assert toks.min() >= 0 and toks.max() < 64


def test_lm_batch_is_learnable_structure():
    """>50% of transitions follow the fixed permutation (10% noise)."""
    cfg = DataConfig(seed=0, vocab=64, seq_len=128, global_batch=8)
    b = lm_batch(cfg, 0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # the mode transition per token should dominate
    agree = 0
    total = 0
    trans = {}
    for t, l in zip(toks.ravel(), labels.ravel()):
        trans.setdefault(t, []).append(l)
    for t, ls in trans.items():
        vals, counts = np.unique(ls, return_counts=True)
        agree += counts.max()
        total += len(ls)
    assert agree / total > 0.7


def test_classification_dataset_shapes_and_balance():
    x, y = classification_dataset(0, 500, 32, 5)
    assert x.shape == (500, 32) and y.shape == (500,)
    assert set(np.unique(y)) <= set(range(5))
    x2, y2 = classification_dataset(0, 500, 32, 5)
    np.testing.assert_array_equal(x, x2)  # deterministic


def test_image_dataset_shapes():
    x, y = image_dataset(0, 100, 28, 3, 10)
    assert x.shape == (100, 28, 28, 3) and y.shape == (100,)
    assert np.isfinite(x).all()
