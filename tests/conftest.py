"""Test-suite bootstrap.

If the real `hypothesis` package is unavailable (containers where pip
installs are not possible), alias the deterministic shim in its place
BEFORE test modules import it, so the property tests still run with
seeded example streams instead of erroring at collection.
"""
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_shim

    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies
