"""Train-loop substrate: learning works, grad-accum is equivalent,
checkpoint/restart + failure injection recover exactly, int8 gradient
compression stays unbiased enough to train."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.optim.optimizers import OptConfig, apply_updates, init_state
from repro.train.loop import FailureInjector, TrainConfig, make_train_step, run

CFG = ModelConfig(
    name="toy", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128, vocab=64,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
)
DCFG = DataConfig(seed=0, vocab=64, seq_len=32, global_batch=8)


@pytest.fixture(scope="module")
def api():
    return build(CFG)


def test_loss_decreases(api):
    params = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3))
    step = jax.jit(make_train_step(api.train_loss, tcfg))
    state = init_state(tcfg.opt, params)
    losses = []
    for i in range(60):
        params, state, m = step(params, state, lm_batch(DCFG, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.8, losses[::10]


def test_grad_accum_equivalence(api):
    """accum=4 microbatches == one big batch (same update direction)."""
    params = api.init(jax.random.PRNGKey(1))
    batch = lm_batch(DCFG, 0)
    t1 = TrainConfig(opt=OptConfig(name="sgd", lr=1e-2, grad_clip=1e9))
    t4 = TrainConfig(opt=OptConfig(name="sgd", lr=1e-2, grad_clip=1e9), grad_accum=4)
    s1 = init_state(t1.opt, params)
    s4 = init_state(t4.opt, params)
    p1, _, m1 = jax.jit(make_train_step(api.train_loss, t1))(params, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(api.train_loss, t4))(params, s4, batch)
    # losses are means over the same tokens; micro mean-of-means == mean
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_optimizers_step():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4, 4)) * 0.1, "b": jnp.ones((4,))}
    for name in ["sgd", "nesterov", "adam", "adamw"]:
        ocfg = OptConfig(name=name, lr=1e-2, weight_decay=0.01)
        state = init_state(ocfg, params)
        p2, s2 = apply_updates(ocfg, params, grads, state)
        assert int(s2["step"]) == 1
        assert not np.allclose(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_checkpoint_restart_bit_identical(api, tmp_path):
    """Crash at step 7 -> restore from step-5 checkpoint -> identical params
    to an uninterrupted run (stateless data pipeline replays batches)."""
    d = str(tmp_path / "ck")
    common = dict(
        loss_fn=api.train_loss,
        init_params_fn=lambda: api.init(jax.random.PRNGKey(2)),
        batch_fn=lambda s: lm_batch(DCFG, s),
        num_steps=10,
    )
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3), ckpt_dir=d, ckpt_every=5)
    p_fail, _, info = run(tcfg=tcfg, failure=FailureInjector([7]), **common)
    assert info["restarts"] == 1

    tcfg2 = TrainConfig(opt=OptConfig(lr=1e-3), ckpt_dir=str(tmp_path / "ck2"), ckpt_every=5)
    p_ok, _, info2 = run(tcfg=tcfg2, **common)
    assert info2["restarts"] == 0
    for a, b in zip(jax.tree.leaves(p_fail), jax.tree.leaves(p_ok)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_grad_compression_trains(api):
    params = api.init(jax.random.PRNGKey(3))
    tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=3e-3), compress_grads=True)
    step = jax.jit(make_train_step(api.train_loss, tcfg))
    state = init_state(tcfg.opt, params)
    losses = []
    for i in range(40):
        params, state, m = step(params, state, lm_batch(DCFG, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) * 0.9
