"""Per-site numerics policy: parsing, resolution, serialization, and
the end-to-end guarantees pinned by the mixed-numerics refactor:

* a uniform ``default=plam_sim:16:1`` policy is BIT-identical to the
  pre-refactor flat ``NumericsConfig(mode="plam_sim")`` path;
* a mixed policy (PLAM MLPs + exact-posit attention + f32
  router/lm_head) runs through one train step, checkpoint save/load
  and greedy paged serving;
* a policy round-trips through checkpoint manifest metadata to
  bit-identical logits (dense and MoE).
"""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.core.policy import (
    BoundPolicy,
    NumericsPolicy,
    layer_segments,
    load_policy_arg,
    parse_policy,
    policy_from_dict,
    policy_to_dict,
    policy_to_str,
    site,
    site_for,
)
from repro.models import build

MIXED = ("default=plam_sim:16:1, attn=posit_quant:16:1, "
         "moe.router=f32, lm_head=f32")

DENSE = dict(family="dense", n_layers=2, d_model=32, n_heads=2, n_kv=2,
             head_dim=16, d_ff=64, vocab=50)
MOE = dict(family="moe", n_layers=2, d_model=32, n_heads=2, n_kv=2,
           head_dim=16, d_ff=64, vocab=50, n_experts=4, top_k=2,
           moe_d_ff=32, n_shared_experts=1)


def _tokens(b=2, s=12, vocab=50):
    return jnp.asarray(
        np.random.default_rng(0).integers(0, vocab, (b, s)).astype(np.int32))


def _logits(cfg):
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    logits, _ = api.prefill(params, {"tokens": _tokens(vocab=cfg.vocab)})
    return np.asarray(logits)


# ---------------------------------------------------------------------------
# resolution semantics
# ---------------------------------------------------------------------------

def test_default_and_exact_and_group_precedence():
    p = parse_policy("default=f32, mlp=plam_sim:16:1, mlp.down=posit_quant:8:0")
    assert p.resolve("attn.qkv").mode == "f32"
    assert p.resolve("mlp.up").mode == "plam_sim"
    assert p.resolve("mlp.down").mode == "posit_quant"
    assert p.resolve("mlp.down").n == 8


def test_layer_rules_and_negative_indices():
    p = parse_policy("default=plam_sim:16:1, layers[0,-1]=posit_quant:16:1")
    assert p.resolve("mlp.up", 0, 8).mode == "posit_quant"
    assert p.resolve("mlp.up", 7, 8).mode == "posit_quant"
    assert p.resolve("mlp.up", 3, 8).mode == "plam_sim"
    # layer-free sites (lm_head) never match a layers[] rule
    assert p.resolve("lm_head", None, 8).mode == "plam_sim"
    # role-specific rules beat layers-only rules
    p2 = parse_policy("default=f32, layers[0]=plam_sim:16:1, mlp.up=bf16")
    assert p2.resolve("mlp.up", 0, 4).mode == "bf16"


def test_combined_role_at_layers_selector():
    p = parse_policy("default=f32, attn@layers[2:]=plam_sim:16:1")
    assert p.resolve("attn.qkv", 3, 4).mode == "plam_sim"
    assert p.resolve("attn.qkv", 1, 4).mode == "f32"
    assert p.resolve("mlp.up", 3, 4).mode == "f32"


def test_router_baseline_rule():
    """The old inline f32-router escape hatch is now a policy rule."""
    # uniform legacy config: router stays exact f32
    assert site(NumericsConfig(mode="plam_sim"), "moe.router").mode == "f32"
    # default= does not silently approximate routing
    p = parse_policy("default=plam_sim:16:1")
    assert p.resolve("moe.router").mode == "f32"
    # ...but an explicit moe.router rule does override the baseline
    p2 = parse_policy("default=f32, moe.router=plam_sim:16:1")
    assert p2.resolve("moe.router").mode == "plam_sim"
    # and a moe-group rule does NOT (exact beats group)
    p3 = parse_policy("default=f32, moe=plam_sim:16:1")
    assert p3.resolve("moe.router").mode == "f32"
    assert p3.resolve("moe.expert.up").mode == "plam_sim"


def test_missing_default_raises():
    p = parse_policy("mlp=plam_sim:16:1")
    with pytest.raises(KeyError):
        p.resolve("attn.qkv")


def test_bare_mode_string_is_uniform():
    p = parse_policy("plam_sim:16:1")
    assert p.resolve("attn.qkv").mode == "plam_sim"
    assert p.resolve("moe.router").mode == "f32"  # baseline survives


# ---------------------------------------------------------------------------
# serialization round trips
# ---------------------------------------------------------------------------

def test_policy_dict_and_str_round_trip():
    p = parse_policy(MIXED + ", layers[1:3]=bf16, ssm.proj@layers[-2:]=f32")
    assert policy_from_dict(policy_to_dict(p)) == p
    assert parse_policy(policy_to_str(p)) == p
    # dict form is JSON-safe
    import json
    assert policy_from_dict(json.loads(json.dumps(policy_to_dict(p)))) == p


def test_load_policy_arg_string_and_path(tmp_path):
    import json

    p = parse_policy(MIXED)
    assert load_policy_arg(MIXED) == p
    path = os.path.join(tmp_path, "pol.json")
    with open(path, "w") as f:
        json.dump({"policy": policy_to_dict(p)}, f)
    assert load_policy_arg(path) == p
    # a path-shaped argument that does not exist is an error, not a
    # policy-string fallback (typo'd artifact paths must fail clearly)
    with pytest.raises(FileNotFoundError):
        load_policy_arg(os.path.join(tmp_path, "nope.json"))


# ---------------------------------------------------------------------------
# layer segmentation
# ---------------------------------------------------------------------------

def test_layer_segments_uniform_is_single_scan():
    nc = NumericsConfig(mode="plam_sim")
    assert layer_segments(nc, 8) == [(0, 8, nc)]
    p = parse_policy("default=plam_sim:16:1, attn=f32")
    segs = layer_segments(p, 8)
    assert len(segs) == 1 and isinstance(segs[0][2], BoundPolicy)


def test_layer_segments_splits_on_layer_rules():
    p = parse_policy("default=f32, layers[0,-1]=posit_quant:16:1")
    assert [(a, b) for a, b, _ in layer_segments(p, 8)] == [(0, 1), (1, 6), (7, 1)]
    # offset windows (hybrid groups) segment in absolute coordinates
    assert [(a, b) for a, b, _ in layer_segments(p, 8, 6, 2)] == [(0, 1), (1, 1)]
    assert [(a, b) for a, b, _ in layer_segments(p, 8, 2, 3)] == [(0, 3)]


# ---------------------------------------------------------------------------
# end-to-end pins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("base", [DENSE, MOE], ids=["dense", "moe"])
def test_uniform_policy_bit_identical_to_flat_config(base):
    """Acceptance pin: default=plam_sim:16:1 == NumericsConfig(plam_sim)."""
    cfg_flat = ModelConfig(**base, numerics=NumericsConfig(mode="plam_sim", n=16, es=1))
    cfg_pol = ModelConfig(**base).with_numerics("default=plam_sim:16:1")
    a, b = _logits(cfg_flat), _logits(cfg_pol)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("base", [DENSE, MOE], ids=["dense", "moe"])
def test_policy_checkpoint_metadata_round_trip(base):
    """policy string -> policy -> manifest extra -> restored policy
    produces bit-identical logits."""
    from repro.train import checkpoint as ckpt

    cfg = ModelConfig(**base).with_numerics(MIXED)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, params, extra=ckpt.policy_extra(cfg.numerics))
        restored, manifest = ckpt.restore(d, params)
    policy = ckpt.manifest_policy(manifest)
    assert policy == parse_policy(MIXED)
    cfg2 = ModelConfig(**base).with_numerics(policy)
    api2 = build(cfg2)
    tok = {"tokens": _tokens()}
    a = np.asarray(api.prefill(params, tok)[0])
    b = np.asarray(api2.prefill(restored, tok)[0])
    assert np.array_equal(a, b)


def test_mixed_policy_trains_checkpoints_and_serves():
    """Acceptance pin: the mixed policy survives one train step,
    checkpoint save/load, and greedy paged serving."""
    from repro.optim.optimizers import OptConfig, init_state
    from repro.serving.engine import ContinuousBatchingEngine, PagedServeConfig
    from repro.train import checkpoint as ckpt
    from repro.train.loop import TrainConfig, make_train_step

    cfg = ModelConfig(**MOE).with_numerics(MIXED)
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(opt=OptConfig(name="adamw", lr=1e-3))
    step = jax.jit(make_train_step(api.train_loss, tcfg))
    batch = {"tokens": _tokens(2, 16), "labels": _tokens(2, 16)}
    params, state, metrics = step(params, init_state(tcfg.opt, params), batch)
    assert np.isfinite(float(metrics["loss"]))

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, params, extra=ckpt.policy_extra(cfg.numerics))
        params, _ = ckpt.restore(d, params)

    eng = ContinuousBatchingEngine(
        cfg, params=params,
        pcfg=PagedServeConfig(block_size=8, num_blocks=32, max_slots=2,
                              max_seq_len=40))
    reqs = [eng.submit(list(range(1, 9)), max_new_tokens=4, arrival_step=i)
            for i in range(2)]
    done = eng.run()
    assert all(len(done[r.rid]) == 4 for r in reqs)


def test_layer_range_policy_forward_differs_only_at_selected_layers():
    """layers[0,-1]=posit_quant changes the result vs uniform f32, and
    the segmentation matches a manual per-layer construction."""
    base = dict(DENSE)
    base["n_layers"] = 3
    cfg_u = ModelConfig(**base).with_numerics("default=f32")
    cfg_l = ModelConfig(**base).with_numerics(
        "default=f32, layers[0,-1]=posit_quant:8:0")
    a, b = _logits(cfg_u), _logits(cfg_l)
    assert not np.array_equal(a, b)
    # resolution check: middle layer stays f32
    assert site_for(cfg_l.numerics, "mlp.up", 1, 3).mode == "f32"
    assert site_for(cfg_l.numerics, "mlp.up", 2, 3).mode == "posit_quant"


def test_with_numerics_accepts_config_policy_and_string():
    cfg = ModelConfig(**DENSE)
    nc = NumericsConfig(mode="f32")
    assert cfg.with_numerics(nc).numerics == nc
    p = parse_policy(MIXED)
    assert cfg.with_numerics(p).numerics == p
    assert cfg.with_numerics(MIXED).numerics == p
    assert isinstance(cfg.with_numerics(policy_to_dict(p)).numerics, NumericsPolicy)


def test_reduced_config_preserves_policy():
    from repro.configs import get_config

    cfg = get_config("yi-6b").with_numerics(MIXED).reduced()
    assert dataclasses.replace(cfg).numerics == parse_policy(MIXED)
