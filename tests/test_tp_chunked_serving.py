"""Tensor-parallel + chunked-prefill serving (PR 2 acceptance bar).

Greedy decode must be CONFIGURATION-INVARIANT: chunked prefill and
tensor-parallel sharding are execution strategies, not models, so the
token streams they produce must match the single-device whole-prompt
engine exactly.  The tp>1 cases need a multi-device platform, which a
CPU host only provides via XLA_FLAGS=--xla_force_host_platform_device_count
set BEFORE jax initializes — those run in a subprocess so the rest of
the suite keeps its normal single-device jax.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.models import build
from repro.serving import ContinuousBatchingEngine, PagedServeConfig

CFG = ModelConfig(
    name="toy-tp", family="dense", n_layers=2, d_model=32, n_heads=4,
    n_kv=2, head_dim=8, d_ff=64, vocab=61,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


@pytest.fixture(scope="module")
def params():
    return build(CFG).init(jax.random.PRNGKey(0))


def _run_stream(params, prompts, *, max_new=6, tp=1, chunk=0):
    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=3,
                              max_seq_len=32, tp=tp, prefill_chunk=chunk))
    reqs = [eng.submit(p, max_new_tokens=max_new, arrival_step=i)
            for i, p in enumerate(prompts)]
    done = eng.run()
    return [done[r.rid] for r in reqs], eng


def test_chunked_prefill_token_identical(params):
    """chunk=8 over mixed prompt lengths (shorter than / equal to /
    spanning multiple chunks, ragged tails) reproduces the unchunked
    engine's greedy tokens exactly."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 61, n).tolist() for n in (3, 9, 8, 17, 5)]
    base, _ = _run_stream(params, prompts, chunk=0)
    chunked, eng = _run_stream(params, prompts, chunk=8)
    assert base == chunked
    # 17-token prompt = 3 chunks, 9 = 2, rest 1 each => more prefill
    # calls than requests, and every step's latency was recorded
    assert eng.stats.prefills > len(prompts)
    assert len(eng.stats.step_latency_s) == eng.stats.steps
    assert eng.stats.latency_p95() >= eng.stats.latency_p50() > 0


def test_chunked_prefill_interleaves_with_decode(params):
    """While a long prompt is being chunk-fed, an already-running
    sequence keeps generating: its finish step precedes the long
    request's admission+prefill completion."""
    rng = np.random.default_rng(1)
    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=2,
                              max_seq_len=48, prefill_chunk=4))
    short = eng.submit(rng.integers(0, 61, 4).tolist(), max_new_tokens=3)
    long_req = eng.submit(rng.integers(0, 61, 20).tolist(), max_new_tokens=3,
                          arrival_step=1)
    eng.run()
    # the long prompt needs 5 chunk steps; the short request (admitted
    # step 0) must finish while/<before> those chunks are still feeding
    assert short.finished_step <= long_req.finished_step - 3
    assert len(short.output) == 3 and len(long_req.output) == 3


def test_chunk_width_must_be_block_multiple(params):
    with pytest.raises(ValueError, match="multiple of"):
        ContinuousBatchingEngine(
            CFG, params=params,
            pcfg=PagedServeConfig(block_size=4, prefill_chunk=6))


def test_tp_requires_devices(params):
    need = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="devices"):
        ContinuousBatchingEngine(
            CFG, params=params, pcfg=PagedServeConfig(tp=need))


def test_model_level_chunk_matches_whole_prefill(params):
    """Two chunked prefill calls leave the pool bit-identical to one
    whole-prompt prefill and produce the same final logits."""
    api = build(CFG)
    rng = np.random.default_rng(2)
    plen, bs = 13, 4
    prompt = rng.integers(0, 61, (1, 16)).astype(np.int32)  # padded to 16
    prompt[0, plen:] = 0
    kp0, vp0 = api.paged_pool_init(8, bs, jnp.float32)
    blocks = jnp.asarray([1, 2, 3, 4], jnp.int32)
    logits_a, (kp_a, vp_a) = api.paged_prefill(
        params, jnp.asarray(prompt), kp0, vp0, blocks, jnp.int32(plen))

    kp_b, vp_b = api.paged_pool_init(8, bs, jnp.float32)
    # chunk 1: tokens [0, 8); chunk 2: ragged [8, 13) padded to 16
    logits_b = None
    for start, width in ((0, 8), (8, 8)):
        toks = np.zeros((1, width), np.int32)
        real = min(plen - start, width)
        toks[0, :real] = prompt[0, start:start + real]
        logits_b, (kp_b, vp_b) = api.paged_prefill_chunk(
            params, jnp.asarray(toks), kp_b, vp_b, blocks,
            jnp.int32(start), jnp.int32(real - 1))
    # same K/V written for all real positions (compare the owned blocks
    # up to the prompt length; padding slots differ by design)
    ka = np.asarray(kp_a[:, blocks]).reshape(CFG.n_layers, -1, CFG.n_kv, CFG.hd)
    kb = np.asarray(kp_b[:, blocks]).reshape(CFG.n_layers, -1, CFG.n_kv, CFG.hd)
    np.testing.assert_allclose(ka[:, :plen], kb[:, :plen], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=1e-5, atol=1e-5)
    assert int(np.argmax(np.asarray(logits_a)[0, -1])) == int(
        np.argmax(np.asarray(logits_b)[0, -1]))


_TP_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    from repro.configs.base import ModelConfig
    from repro.core.modes import NumericsConfig
    from repro.models import build
    from repro.serving import ContinuousBatchingEngine, PagedServeConfig

    assert len(jax.devices()) >= 2, jax.devices()

    def stream(cfg, params, tp, chunk, prompts, max_new):
        eng = ContinuousBatchingEngine(cfg, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=3,
                                  max_seq_len=32, tp=tp, prefill_chunk=chunk))
        reqs = [eng.submit(p, max_new_tokens=max_new, arrival_step=i)
                for i, p in enumerate(prompts)]
        done = eng.run()
        return [done[r.rid] for r in reqs]

    # kv=2 divides tp=2: head-sharded shard_map decode path
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, head_dim=8, d_ff=64, vocab=61,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32")
    params = build(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 61, n).tolist() for n in (3, 9, 17, 6)]
    base = stream(cfg, params, 1, 0, prompts, 5)
    tp2 = stream(cfg, params, 2, 8, prompts, 5)
    assert base == tp2, f"tp2+chunked diverged: {base} vs {tp2}"

    # kv=1 < tp=2: GQA fallback, pool sharded on positions (seq_tp)
    cfg1 = ModelConfig(name="t1", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=1, head_dim=8, d_ff=64, vocab=61,
        numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
        act_dtype="float32", param_dtype="float32")
    params1 = build(cfg1).init(jax.random.PRNGKey(1))
    prompts1 = [rng.integers(0, 61, n).tolist() for n in (5, 11)]
    base1 = stream(cfg1, params1, 1, 0, prompts1, 4)
    tp21 = stream(cfg1, params1, 2, 4, prompts1, 4)
    assert base1 == tp21, f"gqa fallback diverged: {base1} vs {tp21}"
    print("TP-IDENTICAL-OK")
""")


@pytest.mark.slow
def test_tp2_chunked_token_identical_forced_devices():
    """tp=2 + chunked prefill on a forced-8-device CPU mesh is
    greedy-token-identical to the tp=1 unchunked engine, for both the
    head-sharded (kv % tp == 0) and GQA-fallback (kv < tp) layouts.

    Runs in a subprocess because the forced device count must be set
    before jax initializes.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src_dir) + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _TP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "TP-IDENTICAL-OK" in proc.stdout
