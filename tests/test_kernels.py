"""Pallas kernel tests (interpret mode) vs pure-jnp oracles."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.numerics import P16, PositSpec, decode, encode
from repro.kernels import (
    plam_dense,
    plam_matmul_bits,
    posit_decode,
    posit_encode,
    posit_quantize,
)
from repro.kernels.ref import plam_dense_ref, plam_matmul_ref, posit_quantize_ref

SPECS = [PositSpec(16, 1), PositSpec(8, 0), PositSpec(16, 2)]
SHAPES = [(8, 16, 8), (32, 32, 32), (17, 23, 9), (128, 64, 130), (1, 7, 1), (256, 128, 64)]


def _rand_bits(rng, shape, spec):
    x = np.float32(rng.standard_normal(shape) * np.exp(rng.uniform(-2, 2, shape)))
    return encode(jnp.asarray(x), spec)


@pytest.mark.parametrize("spec", SPECS, ids=str)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_plam_matmul_kernel_vs_oracle(spec, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = _rand_bits(rng, (m, k), spec)
    b = _rand_bits(rng, (k, n), spec)
    ref = np.asarray(plam_matmul_ref(a, b, spec))
    ker = np.asarray(plam_matmul_bits(a, b, spec, bm=16, bn=16, bk=16, interpret=True))
    np.testing.assert_allclose(ker, ref, rtol=1e-4, atol=1e-4)


def test_plam_matmul_block_shape_sweep():
    """Result must be block-shape independent (accumulation assoc.)."""
    spec = P16
    rng = np.random.default_rng(42)
    a = _rand_bits(rng, (48, 64), spec)
    b = _rand_bits(rng, (64, 40), spec)
    ref = np.asarray(plam_matmul_ref(a, b, spec))
    for bm, bn, bk in [(8, 8, 8), (16, 32, 16), (48, 40, 64), (128, 128, 128)]:
        ker = np.asarray(plam_matmul_bits(a, b, spec, bm=bm, bn=bn, bk=bk, interpret=True))
        np.testing.assert_allclose(ker, ref, rtol=1e-4, atol=1e-4)


def test_plam_matmul_zero_and_sign_handling():
    spec = P16
    a = encode(jnp.asarray(np.float32([[0.0, -1.5, 2.0], [1.0, 0.0, -4.0]])), spec)
    b = encode(jnp.asarray(np.float32([[1.0, 0.0], [-2.0, 3.0], [0.5, -1.0]])), spec)
    ref = np.asarray(plam_matmul_ref(a, b, spec))
    ker = np.asarray(plam_matmul_bits(a, b, spec, bm=8, bn=8, bk=8, interpret=True))
    np.testing.assert_allclose(ker, ref, rtol=1e-6, atol=1e-6)


def test_plam_dense_batched():
    spec = P16
    rng = np.random.default_rng(7)
    x = jnp.asarray(np.float32(rng.standard_normal((4, 6, 32))))  # batch dims
    w = _rand_bits(rng, (32, 16), spec)
    ref = np.asarray(plam_dense_ref(np.reshape(x, (24, 32)), w, spec)).reshape(4, 6, 16)
    out = np.asarray(plam_dense(x, w, spec, bm=16, bn=16, bk=16, interpret=True))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("spec", SPECS, ids=str)
@pytest.mark.parametrize("shape", [(16, 128), (37, 211), (1, 5), (300, 300)], ids=str)
def test_codec_kernels_vs_oracle(spec, shape):
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.float32(rng.standard_normal(shape) * np.exp(rng.uniform(-10, 10, shape))))
    q_k = np.asarray(posit_quantize(x, spec, block=(8, 128), interpret=True))
    q_r = np.asarray(posit_quantize_ref(x, spec))
    assert np.array_equal(q_k, q_r)
    e_k = np.asarray(posit_encode(x, spec, block=(8, 128), interpret=True))
    e_r = np.asarray(encode(x, spec))
    assert np.array_equal(e_k, e_r)
    d_k = np.asarray(posit_decode(e_r, spec, block=(8, 128), interpret=True))
    d_r = np.asarray(decode(e_r, spec))
    assert np.array_equal(d_k, d_r)


def test_codec_kernel_nd_shapes():
    spec = P16
    rng = np.random.default_rng(4)
    x = jnp.asarray(np.float32(rng.standard_normal((3, 5, 7, 11))))
    q_k = np.asarray(posit_quantize(x, spec, block=(8, 128), interpret=True))
    q_r = np.asarray(posit_quantize_ref(x, spec))
    assert np.array_equal(q_k, q_r)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=1, max_value=40),
    st.sampled_from([(8, 8, 8), (16, 16, 16), (32, 8, 16)]),
)
def test_hypothesis_matmul_shapes(m, k, n, blocks):
    """Property: kernel == oracle for arbitrary small shapes/blocks."""
    spec = P16
    rng = np.random.default_rng(m * 1600 + k * 40 + n)
    a = _rand_bits(rng, (m, k), spec)
    b = _rand_bits(rng, (k, n), spec)
    bm, bn, bk = blocks
    ref = np.asarray(plam_matmul_ref(a, b, spec))
    ker = np.asarray(plam_matmul_bits(a, b, spec, bm=bm, bn=bn, bk=bk, interpret=True))
    np.testing.assert_allclose(ker, ref, rtol=1e-4, atol=1e-4)
