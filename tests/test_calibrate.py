"""Greedy mixed-numerics calibration + cost model + policy artifacts."""
import numpy as np
import jax

from repro.configs.base import ModelConfig
from repro.core.policy import parse_policy
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import build
from repro.numerics.calibrate import (
    calibrate,
    default_candidate_sites,
    estimate_cost,
    load_policy_artifact,
    save_policy_artifact,
    site_macs,
    top1_agreement,
    unit_mult_cost,
)
from repro.core.modes import NumericsConfig

DENSE = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                    head_dim=16, d_ff=128, vocab=128)
MOE = ModelConfig(family="moe", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                  head_dim=16, d_ff=128, vocab=128, n_experts=4, top_k=2,
                  moe_d_ff=64)


def test_unit_cost_ordering():
    """PLAM < exact posit < f32 multiplier cost (the paper's claim at
    the unit-gate proxy level); narrower PLAM is cheaper still."""
    f32 = unit_mult_cost(NumericsConfig(mode="f32"))
    exact16 = unit_mult_cost(NumericsConfig(mode="posit_quant", n=16, es=1))
    plam16 = unit_mult_cost(NumericsConfig(mode="plam_sim", n=16, es=1))
    plam8 = unit_mult_cost(NumericsConfig(mode="plam_sim", n=8, es=0))
    assert plam16 < exact16 < f32
    assert plam8 < plam16


def test_site_macs_and_candidates():
    macs = site_macs(MOE)
    assert {"attn.qkv", "attn.out", "moe.router", "moe.expert.up",
            "lm_head"} <= set(macs)
    assert all(v > 0 for v in macs.values())
    groups = default_candidate_sites(MOE)
    assert "moe.expert" in groups and "attn" in groups and "lm_head" in groups
    assert "moe.router" not in groups  # the router is never a flip candidate


def test_estimate_cost_monotone_in_policy():
    c_f32 = estimate_cost(DENSE, parse_policy("default=f32"))
    c_plam = estimate_cost(DENSE, parse_policy("default=plam_sim:16:1"))
    c_mix = estimate_cost(
        DENSE, parse_policy("default=f32, mlp=plam_sim:16:1"))
    assert c_plam < c_mix < c_f32


def test_calibrate_within_budget_and_artifact_round_trip(tmp_path):
    api = build(DENSE)
    params = api.init(jax.random.PRNGKey(0))
    batch = lm_batch(DataConfig(seed=0, vocab=128, seq_len=32, global_batch=8), 0)
    res = calibrate(DENSE, params, batch, budget=0.05)
    # every decision recorded, and the final policy respects the budget
    assert {d["site"] for d in res.decisions} == set(default_candidate_sites(DENSE))
    final_loss = float(jax.jit(
        build(DENSE.with_numerics(res.policy)).train_loss)(params, batch))
    assert final_loss <= res.base_loss + abs(res.base_loss) * 0.05 + 1e-6
    # calibrated policy is never costlier than the all-base policy
    assert estimate_cost(DENSE, res.policy) <= estimate_cost(
        DENSE, parse_policy("default=f32"))

    path = str(tmp_path / "policy.json")
    save_policy_artifact(path, res.policy, {"base_loss": res.base_loss})
    assert load_policy_artifact(path) == res.policy
    # the artifact is consumable by the CLI loader too
    from repro.core.policy import load_policy_arg

    assert load_policy_arg(path) == res.policy


def test_zero_budget_keeps_base_policy():
    """With a (near-)impossible budget every flip that degrades the
    loss is rejected; the policy stays all-base wherever PLAM hurts."""
    api = build(DENSE)
    params = api.init(jax.random.PRNGKey(0))
    batch = lm_batch(DataConfig(seed=0, vocab=128, seq_len=32, global_batch=8), 0)
    base = float(jax.jit(
        build(DENSE.with_numerics(parse_policy("default=f32"))).train_loss
    )(params, batch))
    res = calibrate(DENSE, params, batch, budget=0.0,
                    target="plam_sim:8:0", fallback=None)
    final = float(jax.jit(
        build(DENSE.with_numerics(res.policy)).train_loss)(params, batch))
    assert final <= base + 1e-6


def test_top1_agreement():
    a = np.zeros((2, 3, 5), np.float32)
    a[..., 1] = 1.0
    b = a.copy()
    assert top1_agreement(a, b) == 1.0
    b[0, 0, 1] = 0.0
    b[0, 0, 2] = 2.0
    assert abs(top1_agreement(a, b) - 5 / 6) < 1e-6
