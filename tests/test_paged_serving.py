"""Continuous-batching serving: allocator, scheduler, paged decode.

Covers the PR 1 acceptance points: block alloc/free round-trips,
admission blocking under a full cache, retirement releasing blocks, and
paged-cache decode producing exactly the tokens the monolithic-cache
engine produces under greedy decode.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core.modes import NumericsConfig
from repro.kernels.decode_attention import (
    decode_attention_ref,
    gather_pages,
    paged_decode_attention_kernel,
    paged_decode_attention_ref,
)
from repro.serving import (
    BlockAllocator,
    ContinuousBatchingEngine,
    Engine,
    OutOfBlocksError,
    PagedServeConfig,
    Request,
    RequestState,
    Scheduler,
    ServeConfig,
    padded_prompt_len,
)

CFG = ModelConfig(
    name="toy-paged", family="dense", n_layers=3, d_model=64, n_heads=4,
    n_kv=2, head_dim=16, d_ff=128, vocab=97,
    numerics=NumericsConfig(mode="posit_quant", n=16, es=1),
    act_dtype="float32", param_dtype="float32",
)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_block_alloc_free_roundtrip():
    al = BlockAllocator(num_blocks=8, block_size=4)
    assert al.num_free == 7  # block 0 reserved scratch
    a = al.allocate(3)
    b = al.allocate(4)
    assert al.num_free == 0
    assert 0 not in a + b and len(set(a + b)) == 7
    with pytest.raises(OutOfBlocksError):
        al.allocate(1)
    al.free(a)
    assert al.num_free == 3
    c = al.allocate(3)
    assert sorted(c) == sorted(a)  # round-trip: freed blocks come back
    al.free(b)
    al.free(c)
    assert al.num_free == 7


def test_free_rejects_double_free_and_bad_ids():
    """free() validates instead of silently corrupting the free list:
    a double-freed block would otherwise be handed to two sequences."""
    al = BlockAllocator(num_blocks=8, block_size=4)
    a = al.allocate(2)
    al.free(a)
    with pytest.raises(ValueError, match="double free"):
        al.free([a[0]])
    with pytest.raises(ValueError, match="out-of-range"):
        al.free([8])
    with pytest.raises(ValueError, match="out-of-range"):
        al.free([-1])
    with pytest.raises(ValueError, match="scratch"):
        al.free([0])
    b = al.allocate(1)
    with pytest.raises(ValueError, match="double free"):
        al.free(b + b)  # duplicate ids within one call
    assert al.num_free == 7  # b[0] landed exactly once despite the raise


def test_blocks_for_rounding():
    al = BlockAllocator(num_blocks=8, block_size=4)
    assert al.blocks_for(1) == 1
    assert al.blocks_for(4) == 1
    assert al.blocks_for(5) == 2
    assert al.blocks_for(0) == 1  # a sequence always owns >= 1 block


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _sched(num_blocks=9, block_size=4, max_slots=4, max_seq_len=32):
    al = BlockAllocator(num_blocks, block_size)
    return Scheduler(al, max_slots, max_seq_len), al


def test_admission_blocks_under_full_cache():
    # 8 allocatable blocks; each request needs 3 (prompt 8 -> 2 blocks,
    # + 3 decode writes spills into a 3rd)
    sched, al = _sched()
    reqs = [Request(rid=i, prompt=list(range(8)), max_new_tokens=4)
            for i in range(4)]
    for r in reqs:
        sched.submit(r)
    admitted = sched.admit(step=0)
    # only 2 of the 4 fit (2*3=6 <= 8 < 9)
    assert [r.rid for r in admitted] == [0, 1]
    assert al.num_free == 2
    assert reqs[2].state is RequestState.WAITING
    # retiring one frees its blocks and the next admission succeeds
    sched.retire(reqs[0], step=5)
    assert al.num_free == 5
    assert sched.admit(step=5)[0].rid == 2


def test_admission_blocks_when_slots_full():
    sched, _ = _sched(num_blocks=64, max_slots=2)
    reqs = [Request(rid=i, prompt=[1, 2], max_new_tokens=2) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    assert len(sched.admit(step=0)) == 2  # slot-bound, not block-bound
    sched.retire(reqs[0], step=1)
    assert len(sched.admit(step=1)) == 1


def test_retire_releases_blocks_and_slot():
    sched, al = _sched()
    r = Request(rid=0, prompt=list(range(5)), max_new_tokens=2)
    sched.submit(r)
    sched.admit(step=0)
    held = al.num_free
    assert r.state is RequestState.RUNNING and r.slot >= 0
    sched.retire(r, step=3)
    assert r.state is RequestState.FINISHED
    assert r.alloc is None and r.slot == -1
    assert al.num_free > held
    assert not sched.running


def test_arrival_step_respected():
    sched, _ = _sched()
    r = Request(rid=0, prompt=[1], max_new_tokens=1, arrival_step=3)
    sched.submit(r)
    assert sched.admit(step=0) == []
    assert sched.admit(step=3) == [r]


def test_oversized_request_rejected():
    sched, _ = _sched(max_seq_len=16)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=list(range(15)), max_new_tokens=8))


def test_unfittable_request_rejected_not_stuck():
    """A request that could NEVER fit the pool is rejected at submit —
    otherwise the engine loop would spin forever on a waiting head."""
    sched, _ = _sched(num_blocks=4, block_size=8, max_seq_len=64)
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(Request(rid=0, prompt=list(range(40)), max_new_tokens=4))


def test_admission_when_pool_exactly_full():
    """A request whose reservation equals the remaining free blocks is
    admitted (<= not <), draining the pool to exactly zero."""
    sched, al = _sched(num_blocks=9, block_size=4, max_seq_len=64)
    # 8 free blocks; prompt 29 + 4 new -> 32 positions = exactly 8 blocks
    req = Request(rid=0, prompt=list(range(29)), max_new_tokens=4)
    sched.submit(req)
    assert sched.blocks_needed(req) == al.num_free == 8
    assert sched.admit(step=0) == [req]
    assert al.num_free == 0
    # the next request waits (pool empty), it is not rejected
    nxt = Request(rid=1, prompt=[1, 2], max_new_tokens=1)
    sched.submit(nxt)
    assert sched.admit(step=0) == []
    assert nxt.state is RequestState.WAITING
    sched.retire(req, step=1)
    assert sched.admit(step=1) == [nxt]


def test_admission_exact_fit_during_chunked_prefill():
    """Guard against an admission double-count: while A is mid-chunk-
    prefill, its in-flight chunk's tail padding lives in blocks A
    ALREADY owns (the padded prompt and the decode tail are
    alternatives under one max in blocks_needed, never a sum), so a new
    request whose whole-lifetime need exactly equals the free pool must
    be admitted — need == free, not need + re-charged padding > free."""
    sched, al = _sched(num_blocks=9, block_size=4, max_seq_len=64)
    a = Request(rid=0, prompt=list(range(12)), max_new_tokens=1)
    sched.submit(a)
    assert sched.admit(step=0) == [a]
    a.prefill_pos = 8  # two of three chunks written: mid-prefill
    a.verified_len = 8
    a.drafted_len = 8
    assert al.num_free == 5
    b = Request(rid=1, prompt=list(range(17)), max_new_tokens=1)
    sched.submit(b)
    # pad(17) = 20 positions -> 5 blocks: exactly the remaining pool
    assert sched.blocks_needed(b) == 5
    assert sched.admit(step=1) == [b]
    assert al.num_free == 0
    # A's unwritten tail (incl. the ragged final chunk's padding up to
    # pad(12) = 12) fits the allocation it already owns — nothing about
    # A's in-flight prefill was charged to the free pool again
    assert padded_prompt_len(a.prompt_len, 4) <= a.alloc.capacity()


def test_admission_exact_fit_during_chunked_prefill_spec():
    """Same exact-fit guarantee with speculative burst headroom in the
    reservation: max(pad(17)=20, 17+2-1=18, 17+2-1+2=20) = 20 -> 5
    blocks, a max not a sum."""
    al = BlockAllocator(9, 4)
    sched = Scheduler(al, 4, 64, spec_k=2)
    a = Request(rid=0, prompt=list(range(12)), max_new_tokens=1)
    sched.submit(a)
    assert sched.admit(step=0) == [a]
    a.prefill_pos = 4  # mid-prefill
    b = Request(rid=1, prompt=list(range(17)), max_new_tokens=2)
    sched.submit(b)
    assert sched.blocks_needed(b) == al.num_free == 5
    assert sched.admit(step=0) == [b]
    assert al.num_free == 0


# ---------------------------------------------------------------------------
# paged attention primitive
# ---------------------------------------------------------------------------

def test_paged_attention_matches_contiguous_oracle():
    rng = np.random.default_rng(0)
    b, h, kv, hd, bs, nb, max_blk = 3, 8, 2, 16, 8, 16, 4
    q = jnp.asarray(rng.standard_normal((b, h, hd)).astype(np.float32))
    kp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)).astype(np.float32))
    bt = jnp.asarray(np.stack(
        [rng.permutation(np.arange(1, nb))[:max_blk] for _ in range(b)]
    ).astype(np.int32))
    lens = jnp.asarray(np.array([5, 17, 32], np.int32))
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    oracle = decode_attention_ref(q, gather_pages(kp, bt), gather_pages(vp, bt), lens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_pallas_kernel_interpret():
    rng = np.random.default_rng(1)
    b, h, kv, hd, bs, nb, max_blk = 2, 4, 2, 16, 8, 8, 3
    q = jnp.asarray(rng.standard_normal((b, h, hd)).astype(np.float32))
    kp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)).astype(np.float32))
    vp = jnp.asarray(rng.standard_normal((nb, bs, kv, hd)).astype(np.float32))
    bt = jnp.asarray(np.array([[1, 2, 3], [4, 5, 6]], np.int32))
    lens = jnp.asarray(np.array([7, 20], np.int32))
    ker = paged_decode_attention_kernel(q, kp, vp, bt, lens, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: paged engine vs monolithic engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def params():
    from repro.models import build

    return build(CFG).init(jax.random.PRNGKey(0))


def test_paged_decode_token_identical_to_monolithic(params):
    """Greedy decode through the paged engine reproduces the static
    engine's tokens exactly, per request, under staggered admission and
    mixed prompt lengths."""
    eng = Engine(CFG, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, n).tolist() for n in (5, 8, 3, 12, 6)]
    max_new = 6

    expect = {}
    for i, p in enumerate(prompts):
        out = eng.generate(
            {"tokens": jnp.asarray(np.asarray(p, np.int32)[None])},
            ServeConfig(max_new_tokens=max_new))
        expect[i] = np.asarray(out)[0].tolist()

    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=3,
                              max_seq_len=32))
    reqs = [cbe.submit(p, max_new_tokens=max_new, arrival_step=i)
            for i, p in enumerate(prompts)]
    done = cbe.run()
    for i, r in enumerate(reqs):
        assert done[r.rid] == expect[i], f"request {i} diverged"
    # mixed-length staggered stream => some slots idled, none corrupted
    assert cbe.stats.generated_tokens == max_new * len(prompts)


def test_engine_admission_throttled_by_cache(params):
    """With blocks for only ~1 sequence, requests run nearly serially —
    and still produce correct tokens (admission waits, never corrupts)."""
    eng = Engine(CFG, params)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 97, 8).tolist() for _ in range(3)]
    max_new = 4
    expect = [
        np.asarray(eng.generate(
            {"tokens": jnp.asarray(np.asarray(p, np.int32)[None])},
            ServeConfig(max_new_tokens=max_new)))[0].tolist()
        for p in prompts
    ]
    # each request needs ceil((8+4-1)/4)=3 blocks; pool has 4 free
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=5, max_slots=4,
                              max_seq_len=16))
    reqs = [cbe.submit(p, max_new_tokens=max_new) for p in prompts]
    done = cbe.run()
    assert [done[r.rid] for r in reqs] == expect
    assert cbe.allocator.num_free == 4  # everything released at the end


def test_engine_retirement_frees_blocks_midstream(params):
    """A short request admitted alongside a long one retires early and
    its blocks are reusable by a later arrival."""
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=16, max_slots=2,
                              max_seq_len=32))
    long_req = cbe.submit([1] * 8, max_new_tokens=10)
    short_req = cbe.submit([2] * 4, max_new_tokens=2)
    late_req = cbe.submit([3] * 4, max_new_tokens=2, arrival_step=3)
    done = cbe.run()
    assert short_req.finished_step < long_req.finished_step
    assert late_req.admitted_step >= 3
    assert len(done[long_req.rid]) == 10
    assert cbe.allocator.num_free == 15


def test_engine_stop_token(params):
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=16, max_slots=2,
                              max_seq_len=64))
    # greedy decode of this prompt emits *some* token; use it as stop
    probe = cbe.submit([5, 6, 7], max_new_tokens=1)
    first = cbe.run()[probe.rid][0]
    req = cbe.submit([5, 6, 7], max_new_tokens=32, stop_token=first)
    out = cbe.run()[req.rid]
    assert out[0] == first and len(out) == 1


def test_engine_rejects_prompt_larger_than_pool(params):
    """Engine-level guard: a prompt that can never fit the whole pool
    raises at submit instead of deadlocking the engine loop."""
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=4, max_slots=2,
                              max_seq_len=64))
    with pytest.raises(ValueError, match="KV blocks"):
        cbe.submit(list(range(40)), max_new_tokens=4)


def test_chunked_sequence_finishes_mid_chunk(params):
    """max_new_tokens=1 with a ragged final chunk: the request finishes
    at prefill completion (never enters decode), its first token matches
    the unchunked engine, and its blocks return to the free list."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, 97, 13).tolist()  # 2 chunks of 8; ragged tail 5

    def one(chunk):
        cbe = ContinuousBatchingEngine(
            CFG, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=16, max_slots=2,
                                  max_seq_len=32, prefill_chunk=chunk))
        req = cbe.submit(prompt, max_new_tokens=1)
        out = cbe.run()[req.rid]
        assert cbe.allocator.num_free == 15  # all blocks released
        assert not cbe.scheduler.has_work()
        return out

    assert one(0) == one(8)

    # stop_token hit on the very first sampled token: same shape of
    # mid-chunk finish, via the early-termination path
    first = one(8)[0]
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=16, max_slots=2,
                              max_seq_len=32, prefill_chunk=8))
    req = cbe.submit(prompt, max_new_tokens=8, stop_token=first)
    out = cbe.run()[req.rid]
    assert out == [first]
    assert cbe.allocator.num_free == 15


def test_engine_admits_exact_fit_while_chunk_prefilling(params):
    """Engine-level twin of the exact-fit admission guard: B's whole-
    lifetime reservation equals the free pool at the moment A is still
    chunk-feeding its prompt.  B must be admitted on that boundary (a
    double-count of A's in-flight chunk tail padding would make the
    pool look one block short), and both streams still finish token-
    identical to their solo unchunked runs."""
    rng = np.random.default_rng(23)
    pa = rng.integers(0, 97, 12).tolist()
    pb = rng.integers(0, 97, 17).tolist()

    def solo(p):
        e = ContinuousBatchingEngine(
            CFG, params=params,
            pcfg=PagedServeConfig(block_size=4, num_blocks=64, max_slots=2,
                                  max_seq_len=32))
        r = e.submit(p, max_new_tokens=1)
        return e.run()[r.rid]

    expect_a, expect_b = solo(pa), solo(pb)
    eng = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=9, max_slots=2,
                              max_seq_len=32, prefill_chunk=4))
    a = eng.submit(pa, max_new_tokens=1)   # 3 blocks of the 8 free
    b = eng.submit(pb, max_new_tokens=1, arrival_step=1)
    eng.step()  # A admitted, first chunk written
    assert 0 < a.prefill_pos < a.prompt_len
    assert eng.scheduler.blocks_needed(b) == eng.allocator.num_free == 5
    eng.step()  # B admitted on the exact-fit boundary
    assert b.admitted_step == 1 and b.state is RequestState.RUNNING
    assert a.prefill_pos < a.prompt_len  # A really was still mid-prefill
    assert eng.allocator.num_free == 0
    done = eng.run()
    assert done[a.rid] == expect_a and done[b.rid] == expect_b
    assert eng.allocator.num_free == 8


def test_block_reuse_after_retirement_no_aliasing(params):
    """Blocks freed by a retired sequence are handed to a new one with
    no stale-KV aliasing: the reuser's tokens equal those it generates
    on a fresh engine (where its blocks were never written before)."""
    rng = np.random.default_rng(11)
    p1 = rng.integers(0, 97, 8).tolist()
    p2 = rng.integers(0, 97, 6).tolist()

    fresh = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=5, max_slots=2,
                              max_seq_len=16))
    ref_req = fresh.submit(p2, max_new_tokens=4)
    expect = fresh.run()[ref_req.rid]

    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=5, max_slots=2,
                              max_seq_len=16))
    # 4 free blocks; req1 takes 3 => req2 (needs 3) must wait and then
    # reuse req1's freed blocks
    r1 = cbe.submit(p1, max_new_tokens=4)
    r2 = cbe.submit(p2, max_new_tokens=4)
    done = cbe.run()
    assert r2.admitted_step > r1.finished_step  # really did wait + reuse
    assert done[r2.rid] == expect
    assert cbe.allocator.num_free == 4


def test_spec_stale_blocks_scrubbed_before_reuse(params):
    """Regression (previously failed): a speculative verify step writes
    k+1 positions, the rejected tail is rolled back, and the sequence
    retires — the rolled-back (and prefill-padding) K/V used to survive
    in the freed blocks, so the free list handed a future sequence
    blocks still holding a previous owner's stale keys.  The engine now
    scrubs the never-committed [verified_len, drafted_len) range at
    retirement: what the free list hands out is zero."""
    rng = np.random.default_rng(13)
    cbe = ContinuousBatchingEngine(
        CFG, params=params,
        pcfg=PagedServeConfig(block_size=4, num_blocks=8, max_slots=1,
                              max_seq_len=24, spec_k=4))
    req = cbe.submit(rng.integers(0, 97, 5).tolist(), max_new_tokens=6)
    cbe.run()
    assert cbe.allocator.num_free == 7
    # the run really did roll back writes (drafted past committed)
    assert req.drafted_len > req.verified_len
    # single request on a fresh engine: blocks were handed out in free
    # list order, so its allocation was the contiguous prefix [1, 2, ..]
    from repro.serving import SequenceAllocation

    alloc = SequenceAllocation(list(range(1, 8)), 4)
    stale = alloc.blocks_covering(req.verified_len, req.drafted_len)
    assert stale, "burst should have written past the committed tail"
    kp = np.asarray(cbe._k_pool)
    vp = np.asarray(cbe._v_pool)
    assert float(np.abs(kp[:, stale]).sum()) == 0.0, (
        "freed blocks still hold rolled-back (never-committed) keys")
    assert float(np.abs(vp[:, stale]).sum()) == 0.0
    # sanity that the assertion has teeth: committed-range blocks WERE
    # written (they hold the sequence's real K/V until reuse)
    committed = [b for b in alloc.blocks_covering(0, req.verified_len)
                 if b not in stale]
    assert float(np.abs(kp[:, committed]).sum()) > 0.0


def test_spec_block_reuse_after_retirement_no_aliasing(params):
    """Block reuse under speculative decoding: a sequence that inherits
    blocks a speculating predecessor dirtied (rolled-back draft tails)
    generates exactly the tokens it generates on a fresh engine."""
    rng = np.random.default_rng(17)
    p1 = rng.integers(0, 97, 8).tolist()
    p2 = rng.integers(0, 97, 6).tolist()

    def pcfg():
        return PagedServeConfig(block_size=4, num_blocks=5, max_slots=2,
                                max_seq_len=16, spec_k=4)

    fresh = ContinuousBatchingEngine(CFG, params=params, pcfg=pcfg())
    ref = fresh.submit(p2, max_new_tokens=4)
    expect = fresh.run()[ref.rid]

    cbe = ContinuousBatchingEngine(CFG, params=params, pcfg=pcfg())
    r1 = cbe.submit(p1, max_new_tokens=4)
    r2 = cbe.submit(p2, max_new_tokens=4)
    done = cbe.run()
    assert r2.admitted_step > r1.finished_step  # really did wait + reuse
    assert done[r2.rid] == expect
    assert cbe.allocator.num_free == 4


def test_moe_family_paged(params):
    del params
    cfg = ModelConfig(
        name="toy-moe-paged", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, head_dim=16, vocab=61, n_experts=4, top_k=2, moe_d_ff=32,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32",
    )
    cbe = ContinuousBatchingEngine(
        cfg, key=jax.random.PRNGKey(1),
        pcfg=PagedServeConfig(block_size=4, num_blocks=32, max_slots=2,
                              max_seq_len=32))
    r = cbe.submit(list(range(6)), max_new_tokens=4)
    out = cbe.run()[r.rid]
    assert len(out) == 4 and all(0 <= t < 61 for t in out)


def test_unsupported_family_raises():
    cfg = ModelConfig(
        name="toy-ssm-paged", family="ssm", n_layers=2, d_model=64, vocab=61,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=8,
        numerics=NumericsConfig(mode="f32"),
        act_dtype="float32", param_dtype="float32", sub_quadratic=True,
    )
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(cfg)
