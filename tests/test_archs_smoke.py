"""Per-architecture smoke tests: reduced configs, one train + serve step.

Each assigned architecture instantiates a REDUCED config of the same
family and runs a forward/train step plus prefill + one decode step on
CPU, asserting output shapes and absence of NaNs.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeSpec
from repro.models import build

SMOKE_SHAPE = ShapeSpec("smoke", seq_len=32, global_batch=2, kind="train")


def _concrete(spec_tree, seed=0):
    """ShapeDtypeStruct tree -> concrete arrays (tokens small-vocab safe)."""
    rng = np.random.default_rng(seed)

    def one(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            if s.shape == ():
                return jnp.int32(0)
            return jnp.asarray(rng.integers(0, 256, s.shape), s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape), s.dtype)

    return jax.tree.map(one, spec_tree)


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_api(request):
    cfg = get_config(request.param).reduced()
    # smoke in f32 numerics stay on the posit path to exercise it
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return request.param, api, params


def test_train_step_shapes_and_finite(arch_api):
    name, api, params = arch_api
    batch = _concrete(api.train_inputs(SMOKE_SHAPE))
    loss, grads = jax.jit(jax.value_and_grad(api.train_loss))(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, name
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), name


def test_prefill_then_decode(arch_api):
    name, api, params = arch_api
    pf_batch = _concrete(api.prefill_inputs(SMOKE_SHAPE))
    logits, caches = jax.jit(api.prefill)(params, pf_batch)
    b = SMOKE_SHAPE.global_batch
    assert logits.shape[0] == b and logits.shape[1] == 1, (name, logits.shape)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), name

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    dec_batch = _concrete(api.decode_inputs(SMOKE_SHAPE))
    dec_batch["token"] = tok
    key = "kv_caches" if "kv_caches" in dec_batch else "caches"
    # decode from the prefill-produced caches where shapes line up
    dec_batch[key] = caches if jax.tree.structure(dec_batch[key]) == jax.tree.structure(caches) else dec_batch[key]
    if "enc_out" in dec_batch:
        dec_batch["enc_out"] = jnp.zeros_like(dec_batch["enc_out"])
    dec_batch["cache_len"] = jnp.int32(SMOKE_SHAPE.seq_len - 1)
    logits2, _ = jax.jit(api.decode_step)(params, dec_batch)
    assert logits2.shape[0] == b, name
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), name


def test_numerics_mode_changes_results(arch_api):
    """posit_quant must actually change values vs f32 (it quantizes)."""
    name, api, params = arch_api
    cfg32 = api.cfg.with_numerics(dataclasses.replace(api.cfg.numerics, mode="f32"))
    api32 = build(cfg32)
    batch = _concrete(api.train_inputs(SMOKE_SHAPE))
    l_q = float(jax.jit(api.train_loss)(params, batch))
    l_f = float(jax.jit(api32.train_loss)(params, batch))
    assert l_q != l_f, name  # quantization must be live
    assert abs(l_q - l_f) / max(abs(l_f), 1e-6) < 0.1, (name, l_q, l_f)
